"""Post-training-quantization arithmetic contract.

This module defines the *integer semantics* shared bit-exactly between:
  - the Pallas kernels (L1) and the pure-jnp oracle (kernels/ref.py),
  - the Rust functional PE model (rust/src/sim/pe.rs),
  - the Rust quantizer (rust/src/quant/).

Scheme (mirrors the paper's Aidge post-training quantization to uint8):
  - activations: uint8, per-tensor affine (zero_point in [0,255]).
    After zero-point subtraction the operand is a 9-bit signed value —
    exactly the width of the J3DAI PE multiplier.
  - weights: int8, per-tensor symmetric (zero_point = 0).
  - accumulate: int32 (the PE's 32-bit accumulator), bias folded in int32.
  - requantize: fixed-point multiplier + right shift (gemmlowp style):
        y = clamp( ((acc * M + (1 << (shift-1))) >> shift) + zp_out,
                   act_min, act_max )
    with the product taken in int64.  Rounding is "half away from zero
    toward +inf" for the positive bias — the same formula on both sides,
    so no ties-to-even mismatch can occur.
  - ReLU  -> act_min = zp_out;  ReLU6 -> act_max = q(6.0).

Scales never appear at inference time; they only determine (M, shift) at
export. For the synthetic-weight golden models we derive (M, shift) from
the reduction depth K so activations neither saturate nor collapse.
"""

from dataclasses import dataclass

import numpy as np

SHIFT = 24  # fixed post-scaling shift used across the stack
ACC_BITS = 32
UINT8_MAX = 255


@dataclass(frozen=True)
class Requant:
    """Requantization parameters for one layer output."""

    mult: int  # int32 fixed-point multiplier
    shift: int  # right shift
    zp_out: int  # output zero point
    act_min: int  # post-activation clamp low (uint8 domain)
    act_max: int  # post-activation clamp high


def requant_for_reduction(k: int, relu: bool = True, relu6: bool = False) -> Requant:
    """Deterministic requant params for a synthetic layer of reduction depth k.

    With int8 weights uniform in [-64, 63] (std ~37) and ReLU'd centered
    activations (std ~30), the accumulator std is ~ sqrt(k)*30*37; scaling
    by 1/(sqrt(k)*48) keeps the requantized output std at a healthy ~23-57
    codes without saturating the uint8 range.  Must match
    rust/src/quant/mod.rs::requant_for_reduction exactly (same f64 math).
    """
    k = max(int(k), 1)
    scale = 1.0 / (np.sqrt(float(k)) * 48.0)
    mult = max(1, int(round(scale * (1 << SHIFT))))
    zp = 128
    lo = zp if relu else 0
    hi = 224 if relu6 else UINT8_MAX  # q(6.0) under the synthetic scale
    return Requant(mult=mult, shift=SHIFT, zp_out=zp, act_min=lo, act_max=hi)


def requant_apply_np(acc: np.ndarray, rq: Requant) -> np.ndarray:
    """Reference numpy implementation of the requant contract."""
    acc = acc.astype(np.int64)
    y = (acc * np.int64(rq.mult) + (np.int64(1) << (rq.shift - 1))) >> rq.shift
    y = y + rq.zp_out
    return np.clip(y, rq.act_min, rq.act_max).astype(np.uint8)


def add_requant_for(k_a: int = 1, k_b: int = 1) -> tuple[Requant, Requant, Requant]:
    """Requant triples (Ma, Mb, out) for a quantized residual add.

    out = clamp(((a - zp) * Ma + (b - zp) * Mb + rnd) >> shift) + zp.
    Both inputs share the synthetic zp=128 domain, so Ma = Mb = 2^(shift-1)
    gives the average of the two branches — stays in range, keeps signal.
    """
    half = 1 << (SHIFT - 1)
    a = Requant(mult=half, shift=SHIFT, zp_out=128, act_min=0, act_max=255)
    b = Requant(mult=half, shift=SHIFT, zp_out=128, act_min=0, act_max=255)
    out = Requant(mult=0, shift=SHIFT, zp_out=128, act_min=0, act_max=255)
    return a, b, out
