"""L2 — quantized JAX forward graphs built on the L1 Pallas kernels.

These are the *golden functional models*: MobileNetV1(alpha), MobileNetV2
and the FPN segmentation network of the paper, in uint8 inference form,
with synthetic deterministic weights (see weights.py). The Rust side
(rust/src/models/ + rust/src/sim/) rebuilds the identical topology with the
identical weight streams and must reproduce these outputs bit-exactly
through the PJRT artifacts.

Topology / naming contract (mirrored in rust/src/models/mod.rs):
  mbv1:   conv0, dw1..dw13, pw1..pw13, avgpool, fc
  mbv2:   conv0, b{i}/exp, b{i}/dw, b{i}/proj (+ residual add), convlast, fc
  fpnseg: backbone mbv1(alpha) conv0..pw13, fpn/lat3..lat5, top-down adds,
          fpn/head, fpn/cls
  channel rounding: ch(c) = max(8, ((c*num//den) + 4)//8*8), alpha = num/den
  conv weight tensor name = "<layer>/w", layout (kh, kw, cin, cout);
  bias stream name = "<layer>" (weights.gen_bias_i32 appends "/bias");
  requant = quantize.requant_for_reduction(K), K = kh*kw*cin.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax.numpy as jnp
import numpy as np

from . import quantize, weights
from .kernels import (
    dwconv3x3_int8,
    global_avgpool,
    matmul_int8,
    qadd,
    qadd_params,
    rq_record,
    upsample2x_nearest,
)

ZP = 128  # global synthetic activation zero point


def ch(c: int, num: int, den: int) -> int:
    """Width-multiplier channel rounding (integer-exact, mirrored in Rust)."""
    return max(8, ((c * num // den) + 4) // 8 * 8)


@dataclass
class Net:
    """Accumulates layers while building; records the layer list for tests."""

    name: str
    layers: list = field(default_factory=list)

    def _rq(self, k: int, relu: bool = True, relu6: bool = False):
        r = quantize.requant_for_reduction(k, relu=relu, relu6=relu6)
        return rq_record(ZP, r.mult, r.shift, r.zp_out, r.act_min, r.act_max)

    # -- ops -----------------------------------------------------------------

    def conv(self, x, lname: str, kh: int, kw: int, cout: int, stride: int = 1,
             relu: bool = True):
        """SAME conv via im2col + the Pallas GEMM kernel."""
        h, w, cin = x.shape
        full = f"{self.name}/{lname}"
        wq = jnp.asarray(weights.gen_weights_i8(full + "/w", (kh, kw, cin, cout)))
        bias = jnp.asarray(weights.gen_bias_i32(full, cout))
        rq = self._rq(kh * kw * cin, relu=relu)
        ph, pw_ = (kh - 1) // 2, (kw - 1) // 2
        oh = (h + 2 * ph - kh) // stride + 1
        ow = (w + 2 * pw_ - kw) // stride + 1
        xp = jnp.full((h + 2 * ph, w + 2 * pw_, cin), np.uint8(ZP), jnp.uint8)
        xp = xp.at[ph : ph + h, pw_ : pw_ + w, :].set(x)
        # im2col in (dy, dx, cin) order — matches w.reshape(kh*kw*cin, cout).
        cols = jnp.concatenate(
            [
                xp[dy : dy + (oh - 1) * stride + 1 : stride,
                   dx : dx + (ow - 1) * stride + 1 : stride, :]
                for dy in range(kh)
                for dx in range(kw)
            ],
            axis=-1,
        ).reshape(oh * ow, kh * kw * cin)
        y = matmul_int8(cols, wq.reshape(kh * kw * cin, cout), bias, rq)
        self.layers.append((lname, "conv", (kh, kw, cin, cout, stride), (oh, ow, cout)))
        return y.reshape(oh, ow, cout)

    def dwconv(self, x, lname: str, stride: int = 1):
        h, w, c = x.shape
        full = f"{self.name}/{lname}"
        wq = jnp.asarray(weights.gen_weights_i8(full + "/w", (3, 3, c)))
        bias = jnp.asarray(weights.gen_bias_i32(full, c))
        rq = self._rq(9)
        y = dwconv3x3_int8(x, wq, bias, rq, stride=stride)
        self.layers.append((lname, "dwconv", (3, 3, c, c, stride), tuple(y.shape)))
        return y

    def add(self, a, b, lname: str):
        y = qadd(a, b, qadd_params())
        self.layers.append((lname, "add", (), tuple(y.shape)))
        return y

    def avgpool(self, x, lname: str = "avgpool"):
        y = global_avgpool(x, jnp.int32(ZP))
        self.layers.append((lname, "avgpool", (), tuple(y.shape)))
        return y

    def dense(self, x, lname: str, n_out: int):
        m, k = x.shape
        full = f"{self.name}/{lname}"
        wq = jnp.asarray(weights.gen_weights_i8(full + "/w", (k, n_out)))
        bias = jnp.asarray(weights.gen_bias_i32(full, n_out))
        rq = self._rq(k, relu=False)
        y = matmul_int8(x, wq, bias, rq)
        self.layers.append((lname, "dense", (1, 1, k, n_out, 1), (m, n_out)))
        return y

    def upsample(self, x, lname: str):
        y = upsample2x_nearest(x)
        self.layers.append((lname, "upsample", (), tuple(y.shape)))
        return y


# -----------------------------------------------------------------------------
# MobileNetV1
# -----------------------------------------------------------------------------

MBV1_CH = [64, 128, 128, 256, 256, 512, 512, 512, 512, 512, 512, 1024, 1024]
MBV1_STRIDE = [1, 2, 1, 2, 1, 2, 1, 1, 1, 1, 1, 2, 1]


def mobilenet_v1(alpha_num: int, alpha_den: int, classes: int = 100,
                 taps: tuple[int, ...] = ()) -> Callable:
    """Quantized MobileNetV1 forward. `taps` = 1-based block indices whose
    pw output is also returned (for the FPN backbone)."""

    def fwd(x):
        net = Net(f"mbv1_{alpha_num}_{alpha_den}")
        x = net.conv(x, "conv0", 3, 3, ch(32, alpha_num, alpha_den), stride=2)
        tapped = []
        for i, (c, s) in enumerate(zip(MBV1_CH, MBV1_STRIDE), start=1):
            x = net.dwconv(x, f"dw{i}", stride=s)
            x = net.conv(x, f"pw{i}", 1, 1, ch(c, alpha_num, alpha_den))
            if i in taps:
                tapped.append(x)
        if taps:
            return tuple(tapped)
        x = net.avgpool(x)
        x = net.dense(x, "fc", classes)
        return (x,)

    return fwd


# -----------------------------------------------------------------------------
# MobileNetV2
# -----------------------------------------------------------------------------

# (expansion t, channels c, repeats n, first stride s)
MBV2_CFG = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]


def mobilenet_v2(alpha_num: int, alpha_den: int, classes: int = 100) -> Callable:
    def fwd(x):
        net = Net(f"mbv2_{alpha_num}_{alpha_den}")
        x = net.conv(x, "conv0", 3, 3, ch(32, alpha_num, alpha_den), stride=2)
        bi = 0
        for t, c, n, s in MBV2_CFG:
            cout = ch(c, alpha_num, alpha_den)
            for r in range(n):
                bi += 1
                stride = s if r == 0 else 1
                cin = x.shape[-1]
                inp = x
                if t != 1:
                    x = net.conv(x, f"b{bi}/exp", 1, 1, cin * t)
                x = net.dwconv(x, f"b{bi}/dw", stride=stride)
                # linear bottleneck: projection has no ReLU
                x = net.conv(x, f"b{bi}/proj", 1, 1, cout, relu=False)
                if stride == 1 and cin == cout:
                    x = net.add(inp, x, f"b{bi}/add")
        x = net.conv(x, "convlast", 1, 1, ch(1280, alpha_num, alpha_den))
        x = net.avgpool(x)
        x = net.dense(x, "fc", classes)
        return (x,)

    return fwd


# -----------------------------------------------------------------------------
# FPN segmentation (MobileNetV1 backbone, paper: alpha = 0.5, 512x384 input)
# -----------------------------------------------------------------------------

FPN_CH = 128  # pyramid width; 128 @ alpha=0.5 lands on the paper's 877 MMACs


def fpn_seg(alpha_num: int, alpha_den: int, classes: int = 19) -> Callable:
    """FPN head over MobileNetV1 taps C3 (pw5, stride 8), C4 (pw11, stride 16),
    C5 (pw13, stride 32). Output logits at stride 8."""

    def fwd(x):
        c3, c4, c5 = mobilenet_v1(alpha_num, alpha_den, taps=(5, 11, 13))(x)
        net = Net(f"fpnseg_{alpha_num}_{alpha_den}")
        pc = ch(FPN_CH, alpha_num, alpha_den)
        l5 = net.conv(c5, "fpn/lat5", 1, 1, pc)
        l4 = net.conv(c4, "fpn/lat4", 1, 1, pc)
        l3 = net.conv(c3, "fpn/lat3", 1, 1, pc)
        def up_to(p, lat, lname):
            """2x nearest upsample cropped to the lateral's spatial dims
            (inputs not divisible by 32 give odd pyramid levels)."""
            u = net.upsample(p, lname)
            return u[: lat.shape[0], : lat.shape[1], :]

        p5 = l5
        p4 = net.add(l4, up_to(p5, l4, "fpn/up5"), "fpn/add4")
        p3 = net.add(l3, up_to(p4, l3, "fpn/up4"), "fpn/add3")
        h = net.conv(p3, "fpn/head", 3, 3, pc)
        h = net.conv(h, "fpn/head2", 3, 3, pc)
        y = net.conv(h, "fpn/cls", 1, 1, classes, relu=False)
        return (y,)

    return fwd


# -----------------------------------------------------------------------------
# Tiny CNN — the quickstart / smoke-test model
# -----------------------------------------------------------------------------


def tinycnn(classes: int = 10) -> Callable:
    def fwd(x):
        net = Net("tinycnn")
        x = net.conv(x, "conv0", 3, 3, 8, stride=2)
        x = net.dwconv(x, "dw1")
        x = net.conv(x, "pw1", 1, 1, 16)
        x = net.avgpool(x)
        x = net.dense(x, "fc", classes)
        return (x,)

    return fwd


# -----------------------------------------------------------------------------
# Registry used by aot.py and the tests. Input shapes are (H, W, C) uint8.
# Reduced-scale variants: full 256x192 interpret-mode tracing is minutes;
# the Rust cycle simulator handles full-size Table I workloads (DESIGN.md).
# -----------------------------------------------------------------------------

MODELS: dict[str, tuple[Callable, tuple[int, int, int]]] = {
    "tinycnn_24x32": (tinycnn(), (24, 32, 3)),
    "mbv1_w25_48x64": (mobilenet_v1(1, 4), (48, 64, 3)),
    "mbv2_w25_48x64": (mobilenet_v2(1, 4), (48, 64, 3)),
    "fpnseg_w25_48x64": (fpn_seg(1, 4), (48, 64, 3)),
}
