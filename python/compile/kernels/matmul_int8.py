"""Pallas INT8 GEMM with fused requantization — the J3DAI MAC-array kernel.

This kernel is the L1 expression of the paper's compute hot spot: every
convolution (after im2col), pointwise convolution and dense layer in the
MobileNet / FPN models lowers to this tile loop.

Hardware adaptation (paper -> Pallas/TPU model, see DESIGN.md):
  - the 6x16x8 = 768-PE MAC array        -> one (BM, BN) MXU-style tile
  - NCB multi-bank SRAM                  -> VMEM blocks (BlockSpec)
  - DMPA column transfer schedule        -> the (m, n, k) grid index maps
  - weight multicast via local routers   -> the shared W block per n-tile
  - 9-bit multiplier / 32-bit accumulate -> (u8 - zp) * i8 in int32 acc
  - fused requant on the store path      -> epilogue at the last k step

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; real-TPU efficiency is estimated analytically in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import kcfg


def _gemm_kernel(x_ref, w_ref, bias_ref, rq_ref, acc_ref, y_ref, *, n_k: int):
    """One (m, n, k) grid step: acc += (x - zp) @ w, requant at k == n_k-1.

    x_ref:    (BM, BK) uint8 activation tile
    w_ref:    (BK, BN) int8 weight tile (multicast operand)
    bias_ref: (1, BN) int32
    rq_ref:   (1, 8) int32 [zp_in, mult, shift, zp_out, act_min, act_max, 0, 0]
    acc_ref:  (BM, BN) int32 accumulator output (aliased across k steps)
    y_ref:    (BM, BN) uint8 requantized output
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.broadcast_to(
            bias_ref[...].astype(jnp.int32), acc_ref.shape
        )

    zp_in = rq_ref[0, 0]
    x = x_ref[...].astype(jnp.int32) - zp_in  # 9-bit signed PE operand
    w = w_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        x,
        w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _requant():
        mult = rq_ref[0, 1].astype(jnp.int64)
        shift = rq_ref[0, 2]
        zp_out = rq_ref[0, 3]
        act_min = rq_ref[0, 4]
        act_max = rq_ref[0, 5]
        acc = acc_ref[...].astype(jnp.int64)
        rnd = jnp.int64(1) << (shift.astype(jnp.int64) - 1)
        y = jax.lax.shift_right_arithmetic(acc * mult + rnd, shift.astype(jnp.int64))
        y = y.astype(jnp.int32) + zp_out
        y = jnp.clip(y, act_min, act_max)
        y_ref[...] = y.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_int8(
    x_q: jax.Array,
    w_q: jax.Array,
    bias: jax.Array,
    rq: jax.Array,
    bm: int = kcfg.BM,
    bn: int = kcfg.BN,
    bk: int = kcfg.BK,
) -> jax.Array:
    """Quantized GEMM: y = requant((x - zp_in) @ w + bias).

    x_q:  (M, K) uint8;  w_q: (K, N) int8;  bias: (N,) int32
    rq:   (8,) int32 requant record (see _gemm_kernel)
    returns (M, N) uint8.
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, (k, k2)
    mp, np_, kp = kcfg.pad_to(m, bm), kcfg.pad_to(n, bn), kcfg.pad_to(k, bk)
    # Pad K with zp so (x - zp) contributes exactly zero to the accumulator.
    zp = rq[0].astype(jnp.uint8)
    x_p = jnp.full((mp, kp), zp, jnp.uint8).at[:m, :k].set(x_q)
    w_p = jnp.zeros((kp, np_), jnp.int8).at[:k, :n].set(w_q)
    b_p = jnp.zeros((1, np_), jnp.int32).at[0, :n].set(bias)
    rq2 = rq.reshape(1, 8)
    n_k = kp // bk

    grid = (mp // bm, np_ // bn, n_k)
    acc, y = pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, 8), lambda i, j, kk: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.int32),
            jax.ShapeDtypeStruct((mp, np_), jnp.uint8),
        ],
        interpret=True,
    )(x_p, w_p, b_p, rq2)
    del acc  # 32-bit accumulator state; only the requantized tile leaves the PE
    return y[:m, :n]


def rq_record(zp_in: int, mult: int, shift: int, zp_out: int, act_min: int, act_max: int):
    """Pack requant parameters into the (8,) int32 record the kernels take."""
    return jnp.array([zp_in, mult, shift, zp_out, act_min, act_max, 0, 0], jnp.int32)
