"""Pallas elementwise kernels: quantized residual add, pooling, and the NLU.

These map to the J3DAI PE's ALU (add/compare paths) and the non-linear
operation unit (NLU), which evaluates activations through a piecewise-linear
approximation — here a 16-segment PWL sigmoid on the 9-bit centered domain,
matching rust/src/sim/pe.rs::nlu_sigmoid exactly.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import kcfg

# ---------------------------------------------------------------------------
# Quantized residual add (MobileNetV2 / FPN lateral adds)
# ---------------------------------------------------------------------------


def _qadd_kernel(a_ref, b_ref, p_ref, y_ref):
    """y = clamp((((a-zpa)*Ma + (b-zpb)*Mb + rnd) >> sh) + zpo, lo, hi).

    p_ref: (1, 8) i32 [zpa, zpb, Ma, Mb, shift, zpo, lo, hi]
    """
    zpa = p_ref[0, 0]
    zpb = p_ref[0, 1]
    ma = p_ref[0, 2].astype(jnp.int64)
    mb = p_ref[0, 3].astype(jnp.int64)
    sh = p_ref[0, 4].astype(jnp.int64)
    zpo = p_ref[0, 5]
    lo = p_ref[0, 6]
    hi = p_ref[0, 7]
    a = (a_ref[...].astype(jnp.int32) - zpa).astype(jnp.int64)
    b = (b_ref[...].astype(jnp.int32) - zpb).astype(jnp.int64)
    rnd = jnp.int64(1) << (sh - 1)
    y = jax.lax.shift_right_arithmetic(a * ma + b * mb + rnd, sh)
    y = y.astype(jnp.int32) + zpo
    y_ref[...] = jnp.clip(y, lo, hi).astype(jnp.uint8)


@jax.jit
def qadd(a: jax.Array, b: jax.Array, params: jax.Array) -> jax.Array:
    """Quantized elementwise add of two uint8 tensors of identical shape."""
    assert a.shape == b.shape, (a.shape, b.shape)
    n = a.size
    blk = kcfg.EW_BLOCK
    np_ = kcfg.pad_to(n, blk)
    zpa = params[0].astype(jnp.uint8)
    zpb = params[1].astype(jnp.uint8)
    a_p = jnp.full((np_,), zpa, jnp.uint8).at[:n].set(a.reshape(-1))
    b_p = jnp.full((np_,), zpb, jnp.uint8).at[:n].set(b.reshape(-1))
    y = pl.pallas_call(
        _qadd_kernel,
        grid=(np_ // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.uint8),
        interpret=True,
    )(a_p, b_p, params.reshape(1, 8))
    return y[:n].reshape(a.shape)


def qadd_params(zpa=128, zpb=128, ma=None, mb=None, shift=24, zpo=128, lo=0, hi=255):
    if ma is None:
        ma = 1 << (shift - 1)
    if mb is None:
        mb = 1 << (shift - 1)
    return jnp.array([zpa, zpb, ma, mb, shift, zpo, lo, hi], jnp.int32)


# ---------------------------------------------------------------------------
# Non-Linear operation Unit: 16-segment PWL sigmoid over the centered domain
# ---------------------------------------------------------------------------

# Breakpoints every 32 codes over [-256, 255] (9-bit domain); slopes/offsets
# are Q8 fixed point: y = (slope * (x - x0) >> 8) + base, y in [0, 255].
# Table = round(sigmoid(x0 / 48.0) * 255) at the breakpoints; constants are
# frozen here AND in rust/src/sim/pe.rs (parity-tested).
NLU_X0 = [-256 + 32 * i for i in range(16)]
NLU_BASE = [1, 2, 5, 9, 17, 30, 53, 86, 128, 168, 202, 225, 238, 246, 250, 253]
NLU_NEXT = NLU_BASE[1:] + [254]
NLU_SLOPE = [((NLU_NEXT[i] - NLU_BASE[i]) * 256) // 32 for i in range(16)]


def _nlu_kernel(x_ref, p_ref, lut_ref, y_ref):
    """PWL sigmoid: x u8 -> center by zp -> 16-segment interp -> u8.

    lut_ref: (3, 16) i32 rows = [x0, base, slope] — the NLU's segment table,
    loaded like any other operand (the hardware NLU holds it in a small ROM).
    """
    zp = p_ref[0, 0]
    x = x_ref[...].astype(jnp.int32) - zp  # [-255, 255]
    seg = jnp.clip((x + 256) >> 5, 0, 15)
    x0 = lut_ref[0, :][seg]
    base = lut_ref[1, :][seg]
    slope = lut_ref[2, :][seg]
    y = base + ((slope * (x - x0)) >> 8)
    y_ref[...] = jnp.clip(y, 0, 255).astype(jnp.uint8)


@jax.jit
def nlu_sigmoid(x: jax.Array, zp: jax.Array) -> jax.Array:
    """Quantized sigmoid through the NLU PWL table. x: any-shape uint8."""
    n = x.size
    blk = kcfg.EW_BLOCK
    np_ = kcfg.pad_to(n, blk)
    x_p = jnp.zeros((np_,), jnp.uint8).at[:n].set(x.reshape(-1))
    p = jnp.array([[zp, 0, 0, 0, 0, 0, 0, 0]], jnp.int32)
    lut = jnp.array([NLU_X0, NLU_BASE, NLU_SLOPE], jnp.int32)
    y = pl.pallas_call(
        _nlu_kernel,
        grid=(np_ // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
            pl.BlockSpec((3, 16), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.uint8),
        interpret=True,
    )(x_p, p, lut)
    return y[:n].reshape(x.shape)


# ---------------------------------------------------------------------------
# Global average pooling (classifier head) — ALU accumulate + requant
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=())
def global_avgpool(x: jax.Array, zp_in: jax.Array) -> jax.Array:
    """(H, W, C) u8 -> (1, C) u8 mean, computed in i32 like the PE ALU.

    Small reduction; runs as plain XLA ops on the host-visible path (the
    paper schedules pooling on the PE ALU — cycle cost modeled in Rust).
    Rounding matches rust sim: (sum + n/2) / n in integer arithmetic over
    the *uint8 codes* (zero-point cancels in the mean).
    """
    h, w, c = x.shape
    n = h * w
    s = jnp.sum(x.astype(jnp.int32), axis=(0, 1))
    y = (s + n // 2) // n
    del zp_in
    return jnp.clip(y, 0, 255).astype(jnp.uint8).reshape(1, c)


def upsample2x_nearest(x: jax.Array) -> jax.Array:
    """(H, W, C) -> (2H, 2W, C) nearest — pure data movement (DMPA copies)."""
    return jnp.repeat(jnp.repeat(x, 2, axis=0), 2, axis=1)
