"""Kernel tiling configuration — the L1 analog of the J3DAI NCB memory budget.

The paper's Neural Computing Block is a multi-banked SRAM feeding 8 SIMD PEs;
a cluster has 16 NCBs and the DMPA moves 1024 bits/cycle between the global
L2 memory and the NCB columns.  On the Pallas side we mirror that hierarchy:

  HBM  <->  VMEM           ==   L2 (5 MB)  <->  NCB SRAM banks
  MXU tile                 ==   cluster's 16x8 = 128-PE MAC array
  BlockSpec grid schedule  ==   DMPA column-transfer schedule

Block sizes are chosen so one (x, w, acc) working set fits the per-cluster
SRAM analog (16 NCBs x 16 KB = 256 KB), exactly the constraint the paper's
mapping solver enforces, and so the M/N tile is a multiple of the 128-lane
MAC array.
"""

# GEMM tile (im2col convolution): bm x bk activations, bk x bn weights,
# bm x bn int32 accumulators.
# Working set = 64*64 (u8) + 64*64 (i8) + 64*64*4 (i32) = 24 KB << 256 KB;
# the slack is the double-buffering headroom the scheduler exploits.
BM = 64
BN = 64
BK = 64

# Depthwise tile: one spatial slab x a channel tile. 8 channels = one NCB's
# PE row; the local router's neighbor access provides the halo.
DW_BC = 8

# Elementwise tile (quantized add / activations / NLU).
EW_BLOCK = 1024


def pad_to(x: int, m: int) -> int:
    """Round x up to a multiple of m."""
    return ((x + m - 1) // m) * m
