"""Pure-jnp/numpy oracle for every L1 kernel — the correctness contract.

Each function here is the straight-line mathematical definition of the
quantized op, with no tiling, padding tricks, or Pallas. The pytest suite
asserts the Pallas kernels match these bit-for-bit; the Rust functional
simulator matches the same contract (checked end-to-end through the PJRT
artifacts).
"""

import numpy as np


def requant_ref(acc: np.ndarray, mult: int, shift: int, zp_out: int, lo: int, hi: int):
    acc = acc.astype(np.int64)
    y = (acc * np.int64(mult) + (np.int64(1) << (shift - 1))) >> shift
    y = y + zp_out
    return np.clip(y, lo, hi).astype(np.uint8)


def matmul_int8_ref(x_q, w_q, bias, rq):
    """x_q (M,K) u8, w_q (K,N) i8, bias (N,) i32, rq (8,) i32 record."""
    zp_in, mult, shift, zp_out, lo, hi = (int(v) for v in np.asarray(rq)[:6])
    x = x_q.astype(np.int64) - zp_in
    w = w_q.astype(np.int64)
    acc = x @ w + bias.astype(np.int64)[None, :]
    # The PE accumulator is 32-bit: assert the synthetic scales keep us in it.
    assert np.all(np.abs(acc) < 2**31), "int32 accumulator overflow in oracle"
    return requant_ref(acc, mult, shift, zp_out, lo, hi)


def dwconv3x3_int8_ref(x_q, w_q, bias, rq, stride=1):
    """x_q (H,W,C) u8, w_q (3,3,C) i8, bias (C,) i32, SAME padding."""
    zp_in, mult, shift, zp_out, lo, hi = (int(v) for v in np.asarray(rq)[:6])
    h, wd, c = x_q.shape
    x = np.full((h + 2, wd + 2, c), zp_in, np.int64)
    x[1 : h + 1, 1 : wd + 1, :] = x_q.astype(np.int64)
    x = x - zp_in
    acc = np.zeros((h, wd, c), np.int64) + bias.astype(np.int64)[None, None, :]
    for dy in range(3):
        for dx in range(3):
            acc += x[dy : dy + h, dx : dx + wd, :] * w_q[dy, dx, :].astype(np.int64)
    assert np.all(np.abs(acc) < 2**31), "int32 accumulator overflow in oracle"
    y = requant_ref(acc, mult, shift, zp_out, lo, hi)
    if stride == 2:
        y = y[::2, ::2, :]
    return y


def qadd_ref(a, b, params):
    zpa, zpb, ma, mb, sh, zpo, lo, hi = (int(v) for v in np.asarray(params)[:8])
    av = a.astype(np.int64) - zpa
    bv = b.astype(np.int64) - zpb
    y = (av * ma + bv * mb + (np.int64(1) << (sh - 1))) >> sh
    y = y + zpo
    return np.clip(y, lo, hi).astype(np.uint8)


def nlu_sigmoid_ref(x, zp):
    from . import elemwise as ew

    xv = x.astype(np.int64) - int(zp)
    seg = np.clip((xv + 256) >> 5, 0, 15).astype(np.int64)
    x0 = np.asarray(ew.NLU_X0, np.int64)[seg]
    base = np.asarray(ew.NLU_BASE, np.int64)[seg]
    slope = np.asarray(ew.NLU_SLOPE, np.int64)[seg]
    y = base + ((slope * (xv - x0)) >> 8)
    return np.clip(y, 0, 255).astype(np.uint8)


def global_avgpool_ref(x, zp_in=0):
    h, w, c = x.shape
    n = h * w
    s = x.astype(np.int64).sum(axis=(0, 1))
    return np.clip((s + n // 2) // n, 0, 255).astype(np.uint8).reshape(1, c)


def conv2d_int8_ref(x_q, w_q, bias, rq, stride=1):
    """Full conv oracle via explicit im2col: x (H,W,Cin) u8, w (kh,kw,Cin,Cout) i8.

    SAME padding (pad = (k-1)//2), stride s. Matches model.py's conv path.
    """
    zp_in = int(np.asarray(rq)[0])
    kh, kw, cin, cout = w_q.shape
    h, wd, _ = x_q.shape
    ph, pw = (kh - 1) // 2, (kw - 1) // 2
    xp = np.full((h + 2 * ph, wd + 2 * pw, cin), zp_in, np.uint8)
    xp[ph : ph + h, pw : pw + wd, :] = x_q
    oh = (h + 2 * ph - kh) // stride + 1
    ow = (wd + 2 * pw - kw) // stride + 1
    cols = np.zeros((oh * ow, kh * kw * cin), np.uint8)
    idx = 0
    for oy in range(oh):
        for ox in range(ow):
            patch = xp[oy * stride : oy * stride + kh, ox * stride : ox * stride + kw, :]
            cols[idx] = patch.reshape(-1)
            idx += 1
    y = matmul_int8_ref(cols, w_q.reshape(kh * kw * cin, cout), bias, rq)
    return y.reshape(oh, ow, cout)
