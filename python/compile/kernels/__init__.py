"""J3DAI L1 Pallas kernels (interpret=True) and their pure oracles."""

from . import kcfg  # noqa: F401
from .dwconv_int8 import dwconv3x3_int8  # noqa: F401
from .elemwise import (  # noqa: F401
    global_avgpool,
    nlu_sigmoid,
    qadd,
    qadd_params,
    upsample2x_nearest,
)
from .matmul_int8 import matmul_int8, rq_record  # noqa: F401
