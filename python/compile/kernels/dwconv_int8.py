"""Pallas INT8 depthwise 3x3 convolution with fused requantization.

MobileNet's depthwise stage does not reduce across channels, so it cannot
use the GEMM MAC array efficiently; on J3DAI it maps to the NCBs' SIMD
lanes with the *local router* providing neighbor access for the 3x3 halo
and the AGU walking the spatial loop. Here each grid step owns a channel
tile (DW_BC = 8 channels = one NCB PE row) and the whole (padded) spatial
slab sits in VMEM — the analog of one NCB SRAM working set.

Stride 1 only; stride-2 layers compute the stride-1 map and the wrapper
subsamples (the hardware AGU does the same walk with a stride register —
cycle cost is modeled in the Rust simulator, not here).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import kcfg


def _dw_kernel(x_ref, w_ref, bias_ref, rq_ref, y_ref, *, h: int, wd: int):
    """x_ref: (h+2, wd+2, bc) uint8 padded slab; w_ref: (3, 3, bc) int8.

    bias_ref: (1, 1, bc) int32; rq_ref: (1, 1, 8) int32; y_ref: (h, wd, bc) u8.
    """
    zp_in = rq_ref[0, 0, 0]
    bc = y_ref.shape[-1]
    acc = jnp.broadcast_to(bias_ref[...].astype(jnp.int32), (h, wd, bc))
    x = x_ref[...].astype(jnp.int32) - zp_in
    # 9 shifted MACs — the local router's neighbor-access pattern.
    for dy in range(3):
        for dx in range(3):
            tap = jax.lax.dynamic_slice(x, (dy, dx, 0), (h, wd, bc))
            acc = acc + tap * w_ref[dy, dx, :].astype(jnp.int32)
    mult = rq_ref[0, 0, 1].astype(jnp.int64)
    shift = rq_ref[0, 0, 2].astype(jnp.int64)
    zp_out = rq_ref[0, 0, 3]
    act_min = rq_ref[0, 0, 4]
    act_max = rq_ref[0, 0, 5]
    rnd = jnp.int64(1) << (shift - 1)
    y = jax.lax.shift_right_arithmetic(acc.astype(jnp.int64) * mult + rnd, shift)
    y = y.astype(jnp.int32) + zp_out
    y_ref[...] = jnp.clip(y, act_min, act_max).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("stride", "bc"))
def dwconv3x3_int8(
    x_q: jax.Array,
    w_q: jax.Array,
    bias: jax.Array,
    rq: jax.Array,
    stride: int = 1,
    bc: int = kcfg.DW_BC,
) -> jax.Array:
    """Quantized depthwise conv: x_q (H, W, C) u8, w_q (3, 3, C) i8, SAME pad.

    bias (C,) i32; rq (8,) i32 record; returns (ceil(H/s), ceil(W/s), C) u8.
    """
    h, wd, c = x_q.shape
    assert w_q.shape == (3, 3, c), w_q.shape
    cp = kcfg.pad_to(c, bc)
    zp = rq[0].astype(jnp.uint8)
    # SAME padding with the zero-point so padded taps contribute 0.
    x_p = jnp.full((h + 2, wd + 2, cp), zp, jnp.uint8)
    x_p = x_p.at[1 : h + 1, 1 : wd + 1, :c].set(x_q)
    w_p = jnp.zeros((3, 3, cp), jnp.int8).at[..., :c].set(w_q)
    b_p = jnp.zeros((1, 1, cp), jnp.int32).at[0, 0, :c].set(bias)
    rq3 = rq.reshape(1, 1, 8)

    grid = (cp // bc,)
    y = pl.pallas_call(
        functools.partial(_dw_kernel, h=h, wd=wd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((h + 2, wd + 2, bc), lambda j: (0, 0, j)),
            pl.BlockSpec((3, 3, bc), lambda j: (0, 0, j)),
            pl.BlockSpec((1, 1, bc), lambda j: (0, 0, j)),
            pl.BlockSpec((1, 1, 8), lambda j: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((h, wd, bc), lambda j: (0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((h, wd, cp), jnp.uint8),
        interpret=True,
    )(x_p, w_p, b_p, rq3)
    y = y[:, :, :c]
    if stride == 2:
        y = y[::2, ::2, :]
    return y
