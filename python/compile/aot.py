"""AOT export: lower every registry model to HLO text for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: the
image's xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit instruction
ids; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs, under artifacts/:
  <model>.hlo.txt          lowered forward graph (uint8 in, uint8 out tuple)
  <model>.input.bin        deterministic synthetic input frame (weights.py)
  <model>.golden.bin       jax-evaluated golden output bytes
  manifest.txt             one line per model:
      name=<n> hlo=<f> input=HxWxC output=<d0xd1[xd2]> golden=<f> inbin=<f>

The Rust integration tests load the manifest, execute the HLO via PJRT on
the .input.bin frame and (a) compare against .golden.bin, (b) compare the
Rust functional simulator's output against the same bytes — closing the
three-layer equivalence loop.
"""

import argparse
import os
import sys

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model as M  # noqa: E402
from . import weights  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default ELIDES big
    # weight literals as `constant({...})`, which xla_extension 0.5.1's
    # text parser silently turns into garbage values.
    return comp.as_hlo_text(True)


def export_model(name: str, outdir: str) -> str:
    fwd, shape = M.MODELS[name]
    spec = jax.ShapeDtypeStruct(shape, np.uint8)
    print(f"[aot] lowering {name} input={shape} ...", flush=True)
    lowered = jax.jit(fwd).lower(spec)
    hlo = to_hlo_text(lowered)
    hlo_path = os.path.join(outdir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(hlo)

    x = weights.gen_input_u8(name, shape)
    in_path = os.path.join(outdir, f"{name}.input.bin")
    x.tofile(in_path)

    print(f"[aot] evaluating golden output for {name} ...", flush=True)
    y = np.asarray(jax.jit(fwd)(x)[0])
    golden_path = os.path.join(outdir, f"{name}.golden.bin")
    y.tofile(golden_path)

    dims = "x".join(str(d) for d in y.shape)
    ishape = "x".join(str(d) for d in shape)
    return (
        f"name={name} hlo={os.path.basename(hlo_path)} input={ishape} "
        f"output={dims} golden={os.path.basename(golden_path)} "
        f"inbin={os.path.basename(in_path)}"
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--models", default="", help="comma list; default = all")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    names = [n for n in args.models.split(",") if n] or list(M.MODELS)
    # merge with any existing manifest so partial re-exports don't drop models
    manifest_path = os.path.join(args.out, "manifest.txt")
    entries: dict[str, str] = {}
    if os.path.exists(manifest_path):
        for line in open(manifest_path):
            if line.strip():
                key = dict(p.split("=", 1) for p in line.split())["name"]
                entries[key] = line.strip()
    for n in names:
        entries[n] = export_model(n, args.out)
    with open(manifest_path, "w") as f:
        f.write("\n".join(entries[k] for k in M.MODELS if k in entries) + "\n")
    print(f"[aot] wrote {len(names)} artifacts to {args.out} ({len(entries)} in manifest)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
