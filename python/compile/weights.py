"""Deterministic synthetic weight generation, shared with the Rust side.

We have no ImageNet/Cityscapes checkpoints in this environment (see
DESIGN.md substitution table), so golden-model weights are generated from a
named PRNG stream that the Rust functional simulator reproduces exactly:

    seed    = fnv1a64(tensor_name)
    z_i     = splitmix64(seed + (i+1) * GAMMA)   # i-th draw of the stream
    int8  w = (z_i >> 40) % 128 - 64             # in [-64, 63]
    int32 b = (z_i >> 32) % 2048 - 1024          # in [-1024, 1023]

The i-th output of a sequential splitmix64 generator is a pure function of
seed + (i+1)*GAMMA, so the stream vectorizes in numpy while the Rust side
(rust/src/quant/weights.rs) iterates sequentially — identical bits.
"""

import numpy as np

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK = (1 << 64) - 1
_GAMMA = 0x9E3779B97F4A7C15


def fnv1a64(name: str) -> int:
    h = _FNV_OFFSET
    for byte in name.encode("utf-8"):
        h ^= byte
        h = (h * _FNV_PRIME) & _MASK
    return h


def _splitmix_stream(seed: int, n: int) -> np.ndarray:
    """First n draws of a splitmix64 generator seeded with `seed`."""
    with np.errstate(over="ignore"):
        i = np.arange(1, n + 1, dtype=np.uint64)
        z = np.uint64(seed & _MASK) + i * np.uint64(_GAMMA)
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return z ^ (z >> np.uint64(31))


class SplitMix64:
    """Sequential splitmix64 — kept for parity tests against the stream."""

    def __init__(self, seed: int):
        self.state = seed & _MASK

    def next_u64(self) -> int:
        self.state = (self.state + _GAMMA) & _MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
        return (z ^ (z >> 31)) & _MASK


def gen_weights_i8(name: str, shape: tuple[int, ...]) -> np.ndarray:
    """int8 weights in [-64, 63] from the named stream."""
    n = int(np.prod(shape))
    z = _splitmix_stream(fnv1a64(name), n)
    vals = ((z >> np.uint64(40)) % np.uint64(128)).astype(np.int64) - 64
    return vals.astype(np.int8).reshape(shape)


def gen_bias_i32(name: str, n: int) -> np.ndarray:
    """int32 biases in [-1024, 1023] from the named stream."""
    z = _splitmix_stream(fnv1a64(name + "/bias"), n)
    vals = ((z >> np.uint64(32)) % np.uint64(2048)).astype(np.int64) - 1024
    return vals.astype(np.int32)


def gen_input_u8(name: str, shape: tuple[int, ...]) -> np.ndarray:
    """uint8 synthetic input frame from the named stream."""
    n = int(np.prod(shape))
    z = _splitmix_stream(fnv1a64(name + "/input"), n)
    return (z >> np.uint64(56)).astype(np.uint8).reshape(shape)
