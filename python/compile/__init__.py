"""J3DAI build-time compile package: L1 Pallas kernels, L2 JAX models, AOT.

Python runs ONCE (`make artifacts`) and never on the request path; the Rust
binary is self-contained after artifacts are built.
"""

import jax

# The requant contract multiplies int32 accumulators by int32 multipliers in
# int64 — enable x64 before any kernel module is imported.
jax.config.update("jax_enable_x64", True)
