"""Pallas INT8 GEMM kernel vs the pure oracle — the core L1 signal."""

import numpy as np
import pytest

import compile  # noqa: F401  (enables x64)
from compile import quantize, weights
from compile.kernels import matmul_int8, rq_record
from compile.kernels import ref


def _rq(k, relu=True, relu6=False):
    r = quantize.requant_for_reduction(k, relu=relu, relu6=relu6)
    return rq_record(128, r.mult, r.shift, r.zp_out, r.act_min, r.act_max)


def _run(m, k, n, tag, relu=True, bm=64, bn=64, bk=64):
    x = weights.gen_input_u8(f"mm/{tag}", (m, k))
    w = weights.gen_weights_i8(f"mm/{tag}/w", (k, n))
    b = weights.gen_bias_i32(f"mm/{tag}", n)
    rq = _rq(k, relu=relu)
    y = np.asarray(matmul_int8(x, w, b, rq, bm=bm, bn=bn, bk=bk))
    yr = ref.matmul_int8_ref(x, w, b, np.asarray(rq))
    np.testing.assert_array_equal(y, yr)
    return y


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),          # degenerate
        (64, 64, 64),       # exactly one tile
        (65, 64, 64),       # one row of M spill
        (64, 65, 64),       # K spill exercises zp-padding correctness
        (64, 64, 65),       # N spill
        (37, 50, 20),       # all-odd
        (128, 256, 96),     # multi-tile all dims
        (1, 2048, 10),      # dense-classifier shape (M=1)
        (3072, 27, 8),      # conv0 im2col shape (K < BK)
    ],
)
def test_matmul_matches_oracle(m, k, n):
    _run(m, k, n, f"{m}x{k}x{n}")


def test_matmul_no_relu_passes_negative_range():
    """relu=False keeps act_min=0 so sub-zero-point codes survive."""
    y = _run(48, 96, 32, "norelu", relu=False)
    assert y.min() < 128, "expected codes below the zero point without ReLU"


def test_matmul_relu_clamps_at_zero_point():
    y = _run(48, 96, 32, "relu", relu=True)
    assert y.min() >= 128


def test_matmul_relu6_clamps_high():
    x = weights.gen_input_u8("mm/r6", (32, 64))
    w = weights.gen_weights_i8("mm/r6/w", (64, 16))
    b = weights.gen_bias_i32("mm/r6", 16)
    rq = _rq(64, relu6=True)
    y = np.asarray(matmul_int8(x, w, b, rq))
    assert y.max() <= 224  # q(6.0) under the synthetic scale
    np.testing.assert_array_equal(y, ref.matmul_int8_ref(x, w, b, np.asarray(rq)))


@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 32), (16, 64, 128), (128, 16, 16)])
def test_matmul_tile_shape_invariance(bm, bn, bk):
    """Result must not depend on the BlockSpec tiling (pure schedule change)."""
    _run(96, 160, 48, "tiles", bm=bm, bn=bn, bk=bk)


def test_matmul_zero_point_padding_is_neutral():
    """K padded with zp contributes exactly 0: compare padded vs unpadded K."""
    x = weights.gen_input_u8("mm/pad", (64, 60))
    w = weights.gen_weights_i8("mm/pad/w", (60, 32))
    b = weights.gen_bias_i32("mm/pad", 32)
    rq = _rq(60)
    y1 = np.asarray(matmul_int8(x, w, b, rq))
    # manually pad K to 64 with zp/zeros — must give identical output
    xp = np.full((64, 64), 128, np.uint8)
    xp[:, :60] = x
    wp = np.zeros((64, 32), np.int8)
    wp[:60, :] = w
    y2 = np.asarray(matmul_int8(xp, wp, b, rq))
    np.testing.assert_array_equal(y1, y2)


def test_matmul_accumulator_is_32bit_safe():
    """Worst-case |acc| for the largest model reduction stays within int32."""
    k_max = 9 * 1024  # 3x3 conv at 1024 input channels
    worst = k_max * 255 * 64 + 1024
    assert worst < 2**31
