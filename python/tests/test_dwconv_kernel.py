"""Pallas depthwise conv kernel vs oracle."""

import numpy as np
import pytest

import compile  # noqa: F401
from compile import quantize, weights
from compile.kernels import dwconv3x3_int8, rq_record
from compile.kernels import ref


def _rq():
    r = quantize.requant_for_reduction(9)
    return rq_record(128, r.mult, r.shift, r.zp_out, r.act_min, r.act_max)


@pytest.mark.parametrize(
    "h,w,c,stride",
    [
        (8, 8, 8, 1),     # one channel tile
        (8, 8, 8, 2),
        (13, 17, 11, 1),  # odd spatial, channel spill
        (13, 17, 11, 2),
        (24, 32, 3, 2),   # tinycnn first dw shape
        (1, 1, 8, 1),     # single pixel (pure-halo case)
        (2, 2, 24, 2),
        (12, 16, 64, 1),  # mbv1-ish inner shape
    ],
)
def test_dwconv_matches_oracle(h, w, c, stride):
    tag = f"dw/{h}x{w}x{c}s{stride}"
    x = weights.gen_input_u8(tag, (h, w, c))
    wq = weights.gen_weights_i8(tag + "/w", (3, 3, c))
    b = weights.gen_bias_i32(tag, c)
    rq = _rq()
    y = np.asarray(dwconv3x3_int8(x, wq, b, rq, stride=stride))
    yr = ref.dwconv3x3_int8_ref(x, wq, b, np.asarray(rq), stride=stride)
    np.testing.assert_array_equal(y, yr)


def test_dwconv_channel_independence():
    """Depthwise means channel c of the output only depends on channel c of
    the input — perturbing channel 0 must leave all other channels intact."""
    x = weights.gen_input_u8("dw/ind", (8, 8, 16))
    wq = weights.gen_weights_i8("dw/ind/w", (3, 3, 16))
    b = weights.gen_bias_i32("dw/ind", 16)
    rq = _rq()
    y0 = np.asarray(dwconv3x3_int8(x, wq, b, rq))
    x2 = x.copy()
    x2[:, :, 0] = 255 - x2[:, :, 0]
    y1 = np.asarray(dwconv3x3_int8(x2, wq, b, rq))
    np.testing.assert_array_equal(y0[:, :, 1:], y1[:, :, 1:])
    assert not np.array_equal(y0[:, :, 0], y1[:, :, 0])


def test_dwconv_same_padding_uses_zero_point():
    """An all-zp input must produce bias-only output everywhere (padding
    contributes nothing even at the corners)."""
    c = 8
    x = np.full((6, 6, c), 128, np.uint8)
    wq = weights.gen_weights_i8("dw/pad/w", (3, 3, c))
    b = weights.gen_bias_i32("dw/pad", c)
    rq = _rq()
    y = np.asarray(dwconv3x3_int8(x, wq, b, rq))
    # every spatial position sees identical (all-zero) input -> constant maps
    for ch in range(c):
        assert len(np.unique(y[:, :, ch])) == 1


def test_dwconv_stride2_equals_stride1_subsampled():
    x = weights.gen_input_u8("dw/s2", (16, 16, 8))
    wq = weights.gen_weights_i8("dw/s2/w", (3, 3, 8))
    b = weights.gen_bias_i32("dw/s2", 8)
    rq = _rq()
    y1 = np.asarray(dwconv3x3_int8(x, wq, b, rq, stride=1))
    y2 = np.asarray(dwconv3x3_int8(x, wq, b, rq, stride=2))
    np.testing.assert_array_equal(y1[::2, ::2, :], y2)
