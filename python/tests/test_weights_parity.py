"""PRNG stream contract: vectorized numpy == sequential splitmix64.

The Rust side implements the sequential form; this test pins the
vectorized numpy form to it so both languages provably draw the same bits.
"""

import numpy as np

import compile  # noqa: F401
from compile import weights


def test_fnv1a64_known_vectors():
    # Pinned values — rust/src/quant/weights.rs has the same table.
    assert weights.fnv1a64("") == 0xCBF29CE484222325
    assert weights.fnv1a64("a") == 0xAF63DC4C8601EC8C
    assert weights.fnv1a64("mbv1_1_4/conv0/w") == weights.fnv1a64("mbv1_1_4/conv0/w")
    assert weights.fnv1a64("x") != weights.fnv1a64("y")


def test_stream_equals_sequential():
    for name in ["a", "mbv1_1_4/conv0/w", "unicode-éé"]:
        seed = weights.fnv1a64(name)
        seq = weights.SplitMix64(seed)
        expected = [seq.next_u64() for _ in range(100)]
        got = weights._splitmix_stream(seed, 100)
        assert [int(v) for v in got] == expected


def test_weight_ranges():
    w = weights.gen_weights_i8("range-test", (1000,))
    assert w.min() >= -64 and w.max() <= 63
    b = weights.gen_bias_i32("range-test", 1000)
    assert b.min() >= -1024 and b.max() <= 1023
    x = weights.gen_input_u8("range-test", (1000,))
    assert x.dtype == np.uint8


def test_weight_determinism_and_name_sensitivity():
    a = weights.gen_weights_i8("name-a", (64,))
    b = weights.gen_weights_i8("name-a", (64,))
    c = weights.gen_weights_i8("name-b", (64,))
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_pinned_first_draws():
    """Absolute pins so a silent PRNG change can never slip through.
    rust/src/quant/weights.rs tests assert the identical values."""
    w = weights.gen_weights_i8("pin", (4,))
    b = weights.gen_bias_i32("pin", 4)
    x = weights.gen_input_u8("pin", (4,))
    assert w.tolist() == [int(v) for v in w]  # shape sanity
    # record the actual draws (frozen once, never edit without the rust twin)
    assert w.tolist() == PIN_W, w.tolist()
    assert b.tolist() == PIN_B, b.tolist()
    assert x.tolist() == PIN_X, x.tolist()


# Frozen expected draws for the "pin" stream (filled from the first run,
# then mirrored in Rust).
PIN_W = [23, 16, -51, 40]
PIN_B = [-244, 620, 735, -874]
PIN_X = [65, 45, 205, 4]
