"""Elementwise kernels (qadd, NLU, pooling, upsample) vs oracle."""

import numpy as np
import pytest

import compile  # noqa: F401
from compile import weights
from compile.kernels import (
    global_avgpool,
    nlu_sigmoid,
    qadd,
    qadd_params,
    upsample2x_nearest,
)
from compile.kernels import ref
from compile.kernels.elemwise import NLU_BASE, NLU_SLOPE, NLU_X0


@pytest.mark.parametrize("shape", [(7, 9, 5), (1,), (1024,), (1025,), (6, 8, 19)])
def test_qadd_matches_oracle(shape):
    a = weights.gen_input_u8(f"qa/{shape}", shape)
    b = weights.gen_input_u8(f"qb/{shape}", shape)
    p = qadd_params()
    y = np.asarray(qadd(a, b, p))
    np.testing.assert_array_equal(y, ref.qadd_ref(a, b, np.asarray(p)))


def test_qadd_identity_zero_point():
    """zp + zp -> zp: the quantized add of two zero tensors is zero."""
    a = np.full((33,), 128, np.uint8)
    y = np.asarray(qadd(a, a, qadd_params()))
    np.testing.assert_array_equal(y, a)


def test_qadd_is_commutative():
    a = weights.gen_input_u8("qc/a", (100,))
    b = weights.gen_input_u8("qc/b", (100,))
    p = qadd_params()
    np.testing.assert_array_equal(np.asarray(qadd(a, b, p)), np.asarray(qadd(b, a, p)))


def test_nlu_matches_oracle_all_codes():
    """Exhaustive over the whole uint8 domain."""
    x = np.arange(256, dtype=np.uint8)
    y = np.asarray(nlu_sigmoid(x, 128))
    np.testing.assert_array_equal(y, ref.nlu_sigmoid_ref(x, 128))


def test_nlu_is_monotone():
    x = np.arange(256, dtype=np.uint8)
    y = np.asarray(nlu_sigmoid(x, 128)).astype(np.int32)
    assert np.all(np.diff(y) >= 0)


def test_nlu_table_shape():
    assert len(NLU_X0) == len(NLU_BASE) == len(NLU_SLOPE) == 16
    assert all(s >= 0 for s in NLU_SLOPE)


@pytest.mark.parametrize("h,w,c", [(4, 4, 8), (7, 5, 3), (1, 1, 16), (6, 8, 64)])
def test_avgpool_matches_oracle(h, w, c):
    x = weights.gen_input_u8(f"ap/{h}x{w}x{c}", (h, w, c))
    y = np.asarray(global_avgpool(x, np.int32(128)))
    np.testing.assert_array_equal(y, ref.global_avgpool_ref(x))


def test_avgpool_constant_input():
    x = np.full((5, 5, 4), 77, np.uint8)
    y = np.asarray(global_avgpool(x, np.int32(128)))
    np.testing.assert_array_equal(y, np.full((1, 4), 77, np.uint8))


def test_upsample2x_nearest():
    x = weights.gen_input_u8("up", (3, 4, 2))
    y = np.asarray(upsample2x_nearest(x))
    assert y.shape == (6, 8, 2)
    for i in range(6):
        for j in range(8):
            np.testing.assert_array_equal(y[i, j], x[i // 2, j // 2])


def test_nlu_approximates_true_sigmoid():
    """The NLU's 16-segment PWL table approximates sigmoid(x/48)*255 to
    within a few codes over the full 9-bit domain — the 'approximation of
    functions' quality claim of the PE's non-linear unit."""
    x = np.arange(256, dtype=np.uint8)
    y = np.asarray(nlu_sigmoid(x, 128)).astype(np.float64)
    xv = x.astype(np.float64) - 128.0
    true = 255.0 / (1.0 + np.exp(-xv / 48.0))
    err = np.abs(y - true)
    assert err.max() <= 8.0, f"max PWL error {err.max()} codes"
    assert err.mean() <= 3.0, f"mean PWL error {err.mean()} codes"
