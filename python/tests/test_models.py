"""L2 model graphs: shapes, determinism, and conv-vs-oracle equivalence."""

import numpy as np
import pytest

import compile  # noqa: F401
from compile import model as M
from compile import quantize, weights
from compile.kernels import ref, rq_record


def test_channel_rounding_contract():
    # Mirrored in rust/src/graph — these exact values are load-bearing.
    assert M.ch(32, 1, 1) == 32
    assert M.ch(32, 1, 4) == 8
    assert M.ch(64, 1, 4) == 16
    assert M.ch(1024, 1, 4) == 256
    assert M.ch(32, 1, 2) == 16
    assert M.ch(512, 1, 2) == 256
    assert M.ch(3, 1, 1) == 8  # floor at 8
    assert M.ch(1280, 1, 4) == 320


@pytest.mark.parametrize("name", list(M.MODELS))
def test_model_output_shape_and_determinism(name):
    fwd, shape = M.MODELS[name]
    x = weights.gen_input_u8(name, shape)
    y1 = np.asarray(fwd(x)[0])
    y2 = np.asarray(fwd(x)[0])
    np.testing.assert_array_equal(y1, y2)
    assert y1.dtype == np.uint8


def test_model_outputs_match_golden_artifacts():
    """If `make artifacts` has run, the current code must still reproduce the
    golden bytes (catches contract drift between aot time and test time)."""
    import os

    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(art, "manifest.txt")
    if not os.path.exists(manifest):
        pytest.skip("artifacts not built")
    for line in open(manifest):
        kv = dict(p.split("=", 1) for p in line.split())
        fwd, shape = M.MODELS[kv["name"]]
        x = np.fromfile(os.path.join(art, kv["inbin"]), np.uint8).reshape(shape)
        y = np.asarray(fwd(x)[0])
        golden = np.fromfile(os.path.join(art, kv["golden"]), np.uint8)
        np.testing.assert_array_equal(y.reshape(-1), golden, err_msg=kv["name"])


def test_conv_layer_matches_im2col_oracle():
    """The Net.conv im2col path == the explicit-loop conv oracle."""
    net = M.Net("mbv1_1_4")  # reuse a model stream name -> same weights
    x = weights.gen_input_u8("convcheck", (10, 12, 5))
    y = net.conv(x, "conv0", 3, 3, 8, stride=2)

    full = "mbv1_1_4/conv0"
    w = weights.gen_weights_i8(full + "/w", (3, 3, 5, 8))
    b = weights.gen_bias_i32(full, 8)
    r = quantize.requant_for_reduction(3 * 3 * 5)
    rq = rq_record(128, r.mult, r.shift, r.zp_out, r.act_min, r.act_max)
    yr = ref.conv2d_int8_ref(x, w, b, np.asarray(rq), stride=2)
    np.testing.assert_array_equal(np.asarray(y), yr)


def test_mbv1_layer_count():
    fwd, shape = M.MODELS["mbv1_w25_48x64"]
    net_layers = []
    # rebuild with a tracing Net by running fwd and counting via layer log
    import jax

    x = weights.gen_input_u8("layercount", shape)
    # count conv ops in the lowered HLO instead: 1 conv0 + 13 pw + 1 fc GEMMs
    # and 13 dwconvs. We count layer records by rebuilding Net manually:
    net = M.Net("probe")
    y = net.conv(x, "c", 3, 3, 8, stride=2)
    assert net.layers[0][1] == "conv"
    # The MBV1 topology constant itself:
    assert len(M.MBV1_CH) == 13 and len(M.MBV1_STRIDE) == 13
    assert M.MBV1_STRIDE.count(2) == 4  # strides 4->32


def test_mbv2_residual_condition():
    """Residual adds appear exactly where stride==1 and cin==cout."""
    # This encodes the paper's observation that branching structures add
    # data movement: count of adds for the standard config.
    n_adds = 0
    cin = M.ch(32, 1, 4)
    for t, c, n, s in M.MBV2_CFG:
        cout = M.ch(c, 1, 4)
        for r in range(n):
            stride = s if r == 0 else 1
            if stride == 1 and cin == cout:
                n_adds += 1
            cin = cout
    assert n_adds == 11  # includes the t=1 first block (cin==cout==8 at a=1/4)
