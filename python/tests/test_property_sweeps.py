"""Hypothesis sweeps over the Pallas kernels' shape/parameter space.

These are the L1 property tests the brief calls for: arbitrary shapes,
strides, zero points and requant parameters, always asserting bit-exact
agreement with the pure oracle.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

import compile  # noqa: F401
from compile import weights
from compile.kernels import dwconv3x3_int8, matmul_int8, nlu_sigmoid, qadd, rq_record
from compile.kernels import ref

_SETTINGS = dict(max_examples=25, deadline=None)


@settings(**_SETTINGS)
@given(
    m=st.integers(1, 90),
    k=st.integers(1, 140),
    n=st.integers(1, 90),
    zp=st.integers(0, 255),
    mult=st.integers(1, 1 << 20),
    shift=st.integers(8, 30),
    zpo=st.integers(0, 255),
    seed=st.integers(0, 10_000),
)
def test_matmul_property(m, k, n, zp, mult, shift, zpo, seed):
    tag = f"prop/{seed}"
    x = weights.gen_input_u8(tag, (m, k))
    w = weights.gen_weights_i8(tag + "/w", (k, n))
    b = weights.gen_bias_i32(tag, n)
    rq = rq_record(zp, mult, shift, zpo, 0, 255)
    y = np.asarray(matmul_int8(x, w, b, rq))
    np.testing.assert_array_equal(y, ref.matmul_int8_ref(x, w, b, np.asarray(rq)))


@settings(**_SETTINGS)
@given(
    h=st.integers(1, 20),
    w=st.integers(1, 20),
    c=st.integers(1, 40),
    stride=st.sampled_from([1, 2]),
    zp=st.integers(0, 255),
    seed=st.integers(0, 10_000),
)
def test_dwconv_property(h, w, c, stride, zp, seed):
    tag = f"dwprop/{seed}"
    x = weights.gen_input_u8(tag, (h, w, c))
    wq = weights.gen_weights_i8(tag + "/w", (3, 3, c))
    b = weights.gen_bias_i32(tag, c)
    rq = rq_record(zp, 116509, 24, 128, 0, 255)
    y = np.asarray(dwconv3x3_int8(x, wq, b, rq, stride=stride))
    yr = ref.dwconv3x3_int8_ref(x, wq, b, np.asarray(rq), stride=stride)
    np.testing.assert_array_equal(y, yr)


@settings(**_SETTINGS)
@given(
    n=st.integers(1, 5000),
    zpa=st.integers(0, 255),
    zpb=st.integers(0, 255),
    ma=st.integers(0, 1 << 24),
    mb=st.integers(0, 1 << 24),
    sh=st.integers(8, 30),
    seed=st.integers(0, 10_000),
)
def test_qadd_property(n, zpa, zpb, ma, mb, sh, seed):
    import jax.numpy as jnp

    a = weights.gen_input_u8(f"qp/a/{seed}", (n,))
    b = weights.gen_input_u8(f"qp/b/{seed}", (n,))
    p = jnp.array([zpa, zpb, ma, mb, sh, 128, 0, 255], jnp.int32)
    y = np.asarray(qadd(a, b, p))
    np.testing.assert_array_equal(y, ref.qadd_ref(a, b, np.asarray(p)))


@settings(**_SETTINGS)
@given(zp=st.integers(0, 255), n=st.integers(1, 3000), seed=st.integers(0, 10_000))
def test_nlu_property(zp, n, seed):
    x = weights.gen_input_u8(f"nlup/{seed}", (n,))
    y = np.asarray(nlu_sigmoid(x, zp))
    np.testing.assert_array_equal(y, ref.nlu_sigmoid_ref(x, zp))
