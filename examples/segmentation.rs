//! Segmentation pipeline — the paper's §IV-B2 scenario: the adapted FPN
//! network (MobileNetV1 alpha=0.5 backbone) for pixel-level prediction.
//! Shows the full-scale PPA (877 MMACs, 7.43 ms, 63.8 mW @30 FPS in the
//! paper) and renders an ASCII class map from the reduced-scale artifact.

use j3dai::config::ArchConfig;
use j3dai::models;
use j3dai::power::EnergyModel;
use j3dai::runtime::{self, Runtime};
use j3dai::sensor::PixelArray;
use j3dai::sim;
use j3dai::sim::functional::Tensor;

fn main() -> j3dai::Result<()> {
    let cfg = ArchConfig::j3dai();
    let em = EnergyModel::fdsoi28();

    println!("== segmentation pipeline (FPN, MobileNetV1-0.5 backbone) ==\n");

    let g = models::paper_seg();
    let r = sim::simulate(&g, &cfg)?;
    println!("full-scale 512x384 -> stride-8 class map {}:", g.output());
    println!(
        "  {:.0} MMACs, {:.2} ms @200 MHz, MAC eff {:.1}%, {:.1} mW @30 FPS",
        r.total_macs as f64 / 1e6,
        r.latency_ms,
        r.mac_efficiency * 100.0,
        r.power_mw(&em, 30.0).unwrap()
    );
    println!(
        "  200 FPS sustainable: {} (paper prints '-')",
        if r.power_mw(&em, 200.0).is_some() { "yes" } else { "no" }
    );

    // functional segmentation on a synthetic frame through PJRT
    let mut rt = Runtime::new()?;
    rt.load_all(&runtime::default_artifact_dir())?;
    let entry = rt.entry("fpnseg_w25_48x64").expect("artifact").clone();
    let frame = PixelArray::new(7).capture(0, entry.input_shape);
    let out = rt.infer("fpnseg_w25_48x64", &frame)?;

    let (h, w, c) = (entry.output_dims[0], entry.output_dims[1], entry.output_dims[2]);
    println!("\nfunctional class map ({h}x{w}, {c} classes), argmax per cell:");
    const GLYPHS: &[u8] = b"0123456789abcdefghijklmnopqrs";
    for y in 0..h {
        let mut line = String::from("  ");
        for x in 0..w {
            let px = &out[(y * w + x) * c..(y * w + x + 1) * c];
            let am = px.iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, _)| i).unwrap();
            line.push(GLYPHS[am % GLYPHS.len()] as char);
        }
        println!("{line}");
    }

    // cross-check against the functional Rust PE model
    let g_small = models::artifact_graph("fpnseg_w25_48x64").unwrap();
    let y = j3dai::sim::functional::run_final(&g_small, &Tensor::new(entry.input_shape, frame.data.clone()));
    assert_eq!(y.data, out, "PJRT and PE-model segmentation maps must agree");
    println!("\nPE-model cross-check: identical bytes ✓");
    println!("segmentation OK");
    Ok(())
}
