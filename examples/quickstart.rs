//! Quickstart — the end-to-end driver: synthetic sensor frames stream
//! through the coordinator, each inference executes the AOT JAX artifact
//! through PJRT (functional result) while the cycle simulator accounts the
//! accelerator's latency/energy, exactly as `j3dai serve` does.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use j3dai::config::ArchConfig;
use j3dai::coordinator::{Coordinator, CoordinatorConfig};
use j3dai::runtime;

fn main() -> j3dai::Result<()> {
    let dir = runtime::default_artifact_dir();
    println!("== J3DAI quickstart ==");
    println!("artifacts: {}", dir.display());

    let coord = Coordinator::new(
        &dir,
        CoordinatorConfig { target_fps: 60.0, frames: 30, arch: ArchConfig::j3dai() },
    )?;
    println!("loaded models: {:?}", coord.model_names());

    let stats = coord.run_model("tinycnn_24x32")?;
    println!(
        "\n{}: {} frames, achieved {:.1} FPS (target 60)",
        stats.model, stats.frames, stats.achieved_fps
    );
    println!(
        "PJRT service time: mean {:.0} us, p99 {:.0} us",
        stats.mean_service_us, stats.p99_service_us
    );
    println!(
        "modeled accelerator: {:.3} ms/inference, {:.1} mW at 60 FPS",
        stats.modeled_latency_ms, stats.modeled_power_mw_at_fps
    );
    let classes: Vec<usize> = stats.records.iter().map(|r| r.top_class).collect();
    println!("per-frame classes: {classes:?}");
    println!("\nquickstart OK");
    Ok(())
}
