//! Functional-interpreter perf probe (used by the §Perf iteration log in
//! EXPERIMENTS.md): wallclock of the Rust PE-model forward per artifact.

use std::time::Instant;

fn main() {
    for name in ["tinycnn_24x32", "mbv1_w25_48x64", "mbv2_w25_48x64", "fpnseg_w25_48x64"] {
        let g = j3dai::models::artifact_graph(name).unwrap();
        let x = j3dai::sim::functional::synthetic_input(name, g.input);
        // warmup
        let _ = j3dai::sim::functional::run_final(&g, &x);
        let t = Instant::now();
        let iters = 5;
        for _ in 0..iters {
            let _ = j3dai::sim::functional::run_final(&g, &x);
        }
        println!("{name}: {:.2} ms/run", t.elapsed().as_secs_f64() * 1e3 / iters as f64);
    }
}
