//! Classification pipeline — the paper's §IV-B1 scenario: MobileNetV1/V2
//! feature extraction on the sensor, at both operating points (30 FPS
//! surveillance, 200 FPS high-speed). Runs the reduced-scale artifact
//! through PJRT for functional results and the full-scale 256x192 graphs
//! through the cycle simulator for the paper's PPA numbers.

use j3dai::config::ArchConfig;
use j3dai::coordinator::{Coordinator, CoordinatorConfig};
use j3dai::models;
use j3dai::power::EnergyModel;
use j3dai::{runtime, sim};

fn main() -> j3dai::Result<()> {
    let cfg = ArchConfig::j3dai();
    let em = EnergyModel::fdsoi28();

    println!("== classification pipeline (MobileNetV1 / V2) ==\n");

    // 1. full-scale PPA from the cycle simulator (Table I's rows)
    for (g, name) in [(models::paper_mbv1(), "MobileNetV1@256x192"), (models::paper_mbv2(), "MobileNetV2@256x192")] {
        let r = sim::simulate(&g, &cfg)?;
        println!("{name}:");
        println!("  {:.0} MMACs, {} cycles -> {:.2} ms @200 MHz, MAC eff {:.1}%", r.total_macs as f64 / 1e6, r.cycles, r.latency_ms, r.mac_efficiency * 100.0);
        for fps in [30.0, 200.0] {
            match r.power_mw(&em, fps) {
                Some(p) => println!("  @{fps:>3.0} FPS: {:.1} mW, {:.2} TOPs/W", p, r.tops_per_watt(&em, fps).unwrap()),
                None => println!("  @{fps:>3.0} FPS: not sustainable (latency {:.2} ms)", r.latency_ms),
            }
        }
    }

    // 2. functional inference on live synthetic frames through PJRT
    println!("\nfunctional run (reduced-scale artifacts, PJRT):");
    let coord = Coordinator::new(
        &runtime::default_artifact_dir(),
        CoordinatorConfig { target_fps: 200.0, frames: 10, arch: cfg },
    )?;
    for model in ["mbv1_w25_48x64", "mbv2_w25_48x64"] {
        let stats = coord.run_model(model)?;
        println!(
            "  {model}: {} frames, mean {:.1} ms service, classes {:?}",
            stats.frames,
            stats.mean_service_us / 1e3,
            &stats.records.iter().map(|r| r.top_class).collect::<Vec<_>>()[..5.min(stats.records.len())]
        );
    }
    println!("\nclassify_pipeline OK");
    Ok(())
}
