//! Compile report — walks the Aidge-analog export (paper Fig. 4) for each
//! Table I workload and prints the solver's decisions: memory placement,
//! per-layer tiling, PE utilization, transfer engine, program footprint.

use j3dai::compiler;
use j3dai::config::ArchConfig;
use j3dai::models;

fn main() -> j3dai::Result<()> {
    let cfg = ArchConfig::j3dai();
    for g in [models::paper_mbv1(), models::paper_mbv2(), models::paper_seg()] {
        let c = compiler::compile(&g, &cfg)?;
        println!("== {} ==", c.model);
        println!(
            "  layers {} | MMACs {:.0} | params {:.2} MB | peak act {:.2} MB | L2 {} MB",
            g.layers.len(),
            g.total_macs() as f64 / 1e6,
            c.param_bytes as f64 / 1e6,
            c.peak_activation_bytes as f64 / 1e6,
            cfg.l2_bytes() / (1024 * 1024)
        );
        println!(
            "  programs: {} bytes over {} clusters ({} instrs)",
            c.program_bytes(),
            c.cluster_programs.len(),
            c.cluster_programs.iter().map(|p| p.instrs.len()).sum::<usize>()
        );
        let avg_util = c.layer_maps.iter().map(|m| m.pe_utilization).sum::<f64>() / c.layer_maps.len() as f64;
        println!("  mean in-tile PE utilization: {:.1}%", avg_util * 100.0);
        println!("  worst 5 layers by utilization:");
        let mut by_util = c.layer_maps.clone();
        by_util.sort_by(|a, b| a.pe_utilization.partial_cmp(&b.pe_utilization).unwrap());
        for m in by_util.iter().take(5) {
            println!(
                "    {:<30} gemm {:>7}x{:<5}x{:<5} tile {:>3}x{:<4}x{:<3} util {:>5.1}% {}",
                m.name,
                m.m,
                m.k,
                m.n,
                m.bm,
                m.bk,
                m.bn,
                m.pe_utilization * 100.0,
                if m.use_dmpa { "DMPA" } else { "DMA" }
            );
        }
        // the first cluster program's head, as the paper's Fig. 4 "assembly"
        println!("  cluster 0 program head:");
        for line in c.cluster_programs[0].listing().lines().take(8) {
            println!("    {line}");
        }
        println!();
    }
    println!("compile_report OK");
    Ok(())
}
