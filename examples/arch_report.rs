//! Architecture report — renders the structural content of the paper's
//! Fig. 2 (3D partitioning) and Fig. 3 (neural cluster) from the live
//! [`ArchConfig`], plus the Fig. 5 floorplans.

use j3dai::config::ArchConfig;
use j3dai::power::area;
use j3dai::report;

fn main() {
    let c = ArchConfig::j3dai();
    println!("== J3DAI architecture (Fig. 2 / Fig. 3) ==\n");
    println!("┌─ top die ──────────── 40nm ─┐  {}x{} RGB pixels, 1 um pitch", j3dai::sensor::SENSOR_W, j3dai::sensor::SENSOR_H);
    println!("│   pixel matrix (12 Mpix)    │");
    println!("├─ Cu-Cu hybrid bonding ──────┤");
    println!("│ middle die ────────── 28nm  │  analog readout 6 mm², ISP,");
    println!("│   RISC-V host ({} KB I / {} KB D), L2 {} MB", c.host_imem_bytes / 1024, c.host_dmem_bytes / 1024, c.l2_middle_bytes / (1024 * 1024));
    println!("├─ {} HD-TSV ({} data, 1 um dia, 2 um pitch) ─┤", c.tsv_total, c.tsv_data);
    println!("│ bottom die ────────── 28nm  │  DNN accelerator + L2 {} MB", c.l2_bottom_bytes / (1024 * 1024));
    println!("└─────────────────────────────┘\n");

    println!("DNN system @{:.0} MHz, {:.2} V:", c.freq_mhz, c.voltage);
    println!("  {} neural clusters x {} NCBs x {} PEs = {} MAC/cycle ({:.1} GOPS peak)",
        c.clusters, c.ncbs_per_cluster, c.pes_per_ncb, c.macs_per_cycle(), c.peak_gops());
    println!("  NCB SRAM: {} KB x {} banks (flattened, fully generic)", c.ncb_sram_bytes / 1024, c.ncb_sram_banks);
    println!("  local SRAM total: {} KB; L2 total: {} MB in {} blocks", c.local_sram_bytes() / 1024, c.l2_bytes() / (1024 * 1024), c.l2_blocks);
    println!("  DMPA: {} bits/cycle ({} B/cycle); DMA bus: {} bits", c.dmpa_bits, c.dmpa_bits / 8, c.dma_bus_bits);
    println!("  1 MB via DMPA: {} cycles | via DMA: {} cycles\n", c.dmpa_cycles(1_000_000), c.dma_cycles(1_000_000));

    println!("neural cluster (Fig. 3):");
    println!("  [controller+imem] -> broadcast -> {} x NCB", c.ncbs_per_cluster);
    println!("  [AGU] multidim addresses   [AIU] hw loops drive routing");
    println!("  [DMPA] -> CCONNECT columns -> NCB banks | [cluster router + multicast reg]\n");

    print!("{}", report::render_floorplan(&area::middle_die(&c)));
    print!("{}", report::render_floorplan(&area::bottom_die(&c)));
    println!("\narch_report OK");
}
