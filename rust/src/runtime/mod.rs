//! PJRT runtime — loads the AOT-compiled JAX artifacts (HLO text) and
//! executes them on the CPU PJRT client from the Rust request path.
//!
//! Interchange is HLO *text*: the image's xla_extension 0.5.1 rejects
//! jax >= 0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md). Python never runs at
//! request time — `make artifacts` is the only compile step.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::graph::Shape;
use crate::sim::functional::Tensor;

/// One artifact's manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub hlo_path: PathBuf,
    pub input_shape: Shape,
    /// Output dims as written by aot.py (2 or 3 dims).
    pub output_dims: Vec<usize>,
    pub golden_path: PathBuf,
    pub input_path: PathBuf,
}

/// Parse `artifacts/manifest.txt`.
pub fn load_manifest(dir: &Path) -> crate::Result<Vec<ArtifactEntry>> {
    let text = std::fs::read_to_string(dir.join("manifest.txt"))
        .with_context(|| format!("reading {}/manifest.txt — run `make artifacts`", dir.display()))?;
    let mut out = Vec::new();
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let kv: HashMap<&str, &str> = line
            .split_whitespace()
            .filter_map(|p| p.split_once('='))
            .collect();
        let need = |k: &str| -> crate::Result<&str> {
            kv.get(k).copied().with_context(|| format!("manifest line missing {k}: {line}"))
        };
        let dims = |s: &str| -> Vec<usize> { s.split('x').map(|d| d.parse().unwrap_or(0)).collect() };
        let ishape = dims(need("input")?);
        anyhow::ensure!(ishape.len() == 3, "input must be HxWxC");
        out.push(ArtifactEntry {
            name: need("name")?.to_string(),
            hlo_path: dir.join(need("hlo")?),
            input_shape: Shape::new(ishape[0], ishape[1], ishape[2]),
            output_dims: dims(need("output")?),
            golden_path: dir.join(need("golden")?),
            input_path: dir.join(need("inbin")?),
        });
    }
    Ok(out)
}

/// A compiled, executable model on the PJRT CPU client.
#[cfg(feature = "pjrt")]
pub struct LoadedModel {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client, many loaded executables.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    models: HashMap<String, LoadedModel>,
}

#[cfg(feature = "pjrt")]

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn new() -> crate::Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        Ok(Runtime { client, models: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact.
    pub fn load(&mut self, entry: ArtifactEntry) -> crate::Result<()> {
        let proto = xla::HloModuleProto::from_text_file(&entry.hlo_path).map_err(to_anyhow)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(to_anyhow)?;
        self.models.insert(entry.name.clone(), LoadedModel { entry, exe });
        Ok(())
    }

    /// Load every artifact in a manifest directory.
    pub fn load_all(&mut self, dir: &Path) -> crate::Result<usize> {
        let entries = load_manifest(dir)?;
        let n = entries.len();
        for e in entries {
            self.load(e)?;
        }
        Ok(n)
    }

    pub fn model_names(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
        self.models.get(name).map(|m| &m.entry)
    }

    /// Execute a model on a uint8 HWC frame; returns the flat uint8 output.
    pub fn infer(&self, name: &str, frame: &Tensor) -> crate::Result<Vec<u8>> {
        let m = self.models.get(name).with_context(|| format!("model {name} not loaded"))?;
        anyhow::ensure!(
            frame.shape == m.entry.input_shape,
            "input shape {} != artifact {}",
            frame.shape,
            m.entry.input_shape
        );
        let s = frame.shape;
        let lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U8,
            &[s.h, s.w, s.c],
            &frame.data,
        )
        .map_err(to_anyhow)?;
        let result = m.exe.execute::<xla::Literal>(&[lit]).map_err(to_anyhow)?;
        let out = result[0][0].to_literal_sync().map_err(to_anyhow)?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple
        let out = out.to_tuple1().map_err(to_anyhow)?;
        out.to_vec::<u8>().map_err(to_anyhow)
    }
}

#[cfg(feature = "pjrt")]
fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("{e:?}")
}

/// Stub runtime for builds without the `pjrt` feature (the xla_extension
/// toolchain image provides the real one). Construction fails with a clear
/// message; every caller already handles `Runtime::new()` errors, and the
/// functional paths (`j3dai metrics`, the cycle simulator, the telemetry
/// stack) don't need PJRT at all.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn new() -> crate::Result<Self> {
        anyhow::bail!(
            "PJRT runtime not built — enable the `pjrt` cargo feature (needs the xla crate \
             from the xla_extension image)"
        )
    }

    pub fn platform(&self) -> String {
        unreachable!("stub Runtime cannot be constructed")
    }

    pub fn load(&mut self, _entry: ArtifactEntry) -> crate::Result<()> {
        unreachable!("stub Runtime cannot be constructed")
    }

    pub fn load_all(&mut self, _dir: &Path) -> crate::Result<usize> {
        unreachable!("stub Runtime cannot be constructed")
    }

    pub fn model_names(&self) -> Vec<&str> {
        unreachable!("stub Runtime cannot be constructed")
    }

    pub fn entry(&self, _name: &str) -> Option<&ArtifactEntry> {
        unreachable!("stub Runtime cannot be constructed")
    }

    pub fn infer(&self, _name: &str, _frame: &Tensor) -> crate::Result<Vec<u8>> {
        unreachable!("stub Runtime cannot be constructed")
    }
}

/// Default artifact directory (repo-relative).
pub fn default_artifact_dir() -> PathBuf {
    // honor an env override for tests running from other cwds
    if let Ok(d) = std::env::var("J3DAI_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_when_present() {
        let dir = default_artifact_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let entries = load_manifest(&dir).unwrap();
        assert!(entries.len() >= 4);
        for e in &entries {
            assert!(e.hlo_path.exists(), "{:?}", e.hlo_path);
            assert!(e.golden_path.exists());
            assert!(e.input_path.exists());
            assert!(e.input_shape.elems() > 0);
        }
    }

    #[test]
    fn manifest_rejects_garbage() {
        let dir = std::env::temp_dir().join("j3dai-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "name=x hlo=x.hlo.txt input=3x3\n").unwrap();
        assert!(load_manifest(&dir).is_err());
    }
}
