//! Quantized NN graph IR — the representation the Aidge-analog compiler
//! consumes (the paper's Fig. 4 pipeline starts from an imported ONNX
//! graph; ours starts here).
//!
//! Layers are topologically ordered; each layer names its input layers by
//! index (index `usize::MAX` denotes the network input). Shape inference,
//! MAC/parameter accounting and memory footprints are computed on
//! construction so the mapper/scheduler and the Table I/II benches all draw
//! from one source of truth.

use std::fmt;

/// Spatial tensor shape (height, width, channels).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl Shape {
    pub fn new(h: usize, w: usize, c: usize) -> Self {
        Shape { h, w, c }
    }

    pub fn elems(&self) -> usize {
        self.h * self.w * self.c
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}x{}", self.h, self.w, self.c)
    }
}

/// Operator kinds supported by the accelerator (paper §III-B: conventional
/// CNN ops — convolutions, depthwise, elementwise, pooling, dense).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Op {
    /// Standard convolution, SAME padding, square kernel.
    Conv { kh: usize, kw: usize, cout: usize, stride: usize, relu: bool },
    /// 3x3 depthwise convolution, SAME padding.
    DwConv { stride: usize },
    /// Fully connected (1x1 on a 1x1 spatial map).
    Dense { out: usize },
    /// Quantized residual add of two inputs.
    Add,
    /// Global average pooling to 1x1.
    GlobalAvgPool,
    /// 2x nearest-neighbor upsample (cropped to the `to` shape).
    Upsample2x { to_h: usize, to_w: usize },
    /// NLU activation through the PWL table (sigmoid approximation).
    NluSigmoid,
}

/// One layer instance in the graph.
#[derive(Debug, Clone)]
pub struct Layer {
    /// Unique name; also the weight-stream name prefix (`<name>/w`).
    pub name: String,
    pub op: Op,
    /// Indices of producer layers (`INPUT` = the network input).
    pub inputs: Vec<usize>,
    pub out_shape: Shape,
    /// Multiply-accumulate operations to compute this layer once.
    pub macs: u64,
    /// Parameter bytes (int8 weights + int32 biases).
    pub param_bytes: u64,
}

/// Marker index for "the network input tensor".
pub const INPUT: usize = usize::MAX;

/// A full network: ordered layers plus the input descriptor.
#[derive(Debug, Clone)]
pub struct Graph {
    pub name: String,
    pub input: Shape,
    pub layers: Vec<Layer>,
}

impl Graph {
    pub fn new(name: impl Into<String>, input: Shape) -> Self {
        Graph { name: name.into(), input, layers: Vec::new() }
    }

    fn shape_of(&self, idx: usize) -> Shape {
        if idx == INPUT { self.input } else { self.layers[idx].out_shape }
    }

    /// Append a layer; returns its index. Computes shape, MACs and params.
    pub fn push(&mut self, name: impl Into<String>, op: Op, inputs: Vec<usize>) -> usize {
        let in_shape = self.shape_of(inputs[0]);
        let (out_shape, macs, param_bytes) = match &op {
            Op::Conv { kh, kw, cout, stride, .. } => {
                let oh = out_dim(in_shape.h, *kh, *stride);
                let ow = out_dim(in_shape.w, *kw, *stride);
                let macs = (oh * ow * kh * kw * in_shape.c * cout) as u64;
                let params = (kh * kw * in_shape.c * cout) as u64 + 4 * *cout as u64;
                (Shape::new(oh, ow, *cout), macs, params)
            }
            Op::DwConv { stride } => {
                let oh = out_dim(in_shape.h, 3, *stride);
                let ow = out_dim(in_shape.w, 3, *stride);
                let macs = (oh * ow * 9 * in_shape.c) as u64;
                let params = (9 * in_shape.c) as u64 + 4 * in_shape.c as u64;
                (Shape::new(oh, ow, in_shape.c), macs, params)
            }
            Op::Dense { out } => {
                let k = in_shape.elems();
                ((Shape::new(1, 1, *out)), (k * out) as u64, (k * out) as u64 + 4 * *out as u64)
            }
            Op::Add => {
                let b = self.shape_of(inputs[1]);
                assert_eq!(in_shape, b, "Add operands must agree: {in_shape} vs {b}");
                (in_shape, 0, 0)
            }
            Op::GlobalAvgPool => (Shape::new(1, 1, in_shape.c), 0, 0),
            Op::Upsample2x { to_h, to_w } => {
                assert!(*to_h <= 2 * in_shape.h && *to_w <= 2 * in_shape.w);
                (Shape::new(*to_h, *to_w, in_shape.c), 0, 0)
            }
            Op::NluSigmoid => (in_shape, 0, 0),
        };
        self.layers.push(Layer { name: name.into(), op, inputs, out_shape, macs, param_bytes });
        self.layers.len() - 1
    }

    /// Total MAC count (the paper's "MMACs" rows).
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    /// Total parameter bytes.
    pub fn total_param_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.param_bytes).sum()
    }

    /// Output shape of the final layer.
    pub fn output(&self) -> Shape {
        self.layers.last().expect("empty graph").out_shape
    }

    /// Number of layers that carry MACs (conv/dw/dense).
    pub fn compute_layers(&self) -> usize {
        self.layers.iter().filter(|l| l.macs > 0).count()
    }

    /// Validate topological order and arities.
    pub fn validate(&self) -> crate::Result<()> {
        for (i, l) in self.layers.iter().enumerate() {
            anyhow::ensure!(!l.inputs.is_empty(), "layer {} has no inputs", l.name);
            for &j in &l.inputs {
                anyhow::ensure!(j == INPUT || j < i, "layer {} uses later layer {}", l.name, j);
            }
            let arity = if matches!(l.op, Op::Add) { 2 } else { 1 };
            anyhow::ensure!(l.inputs.len() == arity, "layer {} arity {} != {}", l.name, l.inputs.len(), arity);
        }
        Ok(())
    }
}

/// SAME-padding output size: pad = (k-1)/2 both sides.
pub fn out_dim(n: usize, k: usize, stride: usize) -> usize {
    let pad = (k - 1) / 2;
    (n + 2 * pad - k) / stride + 1
}

/// Width-multiplier channel rounding — the integer-exact contract shared
/// with `python/compile/model.py::ch`.
pub fn ch(c: usize, num: usize, den: usize) -> usize {
    (((c * num / den) + 4) / 8 * 8).max(8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_rounding_contract() {
        // Twin of python test_models.py::test_channel_rounding_contract.
        assert_eq!(ch(32, 1, 1), 32);
        assert_eq!(ch(32, 1, 4), 8);
        assert_eq!(ch(64, 1, 4), 16);
        assert_eq!(ch(1024, 1, 4), 256);
        assert_eq!(ch(32, 1, 2), 16);
        assert_eq!(ch(512, 1, 2), 256);
        assert_eq!(ch(3, 1, 1), 8);
        assert_eq!(ch(1280, 1, 4), 320);
    }

    #[test]
    fn conv_shape_and_macs() {
        let mut g = Graph::new("t", Shape::new(24, 32, 3));
        let c0 = g.push("conv0", Op::Conv { kh: 3, kw: 3, cout: 8, stride: 2, relu: true }, vec![INPUT]);
        assert_eq!(g.layers[c0].out_shape, Shape::new(12, 16, 8));
        assert_eq!(g.layers[c0].macs, (12 * 16 * 9 * 3 * 8) as u64);
        g.validate().unwrap();
    }

    #[test]
    fn dw_preserves_channels() {
        let mut g = Graph::new("t", Shape::new(16, 16, 24));
        let d = g.push("dw", Op::DwConv { stride: 2 }, vec![INPUT]);
        assert_eq!(g.layers[d].out_shape, Shape::new(8, 8, 24));
        assert_eq!(g.layers[d].macs, (8 * 8 * 9 * 24) as u64);
    }

    #[test]
    fn add_requires_matching_shapes() {
        let mut g = Graph::new("t", Shape::new(8, 8, 8));
        let a = g.push("a", Op::Conv { kh: 1, kw: 1, cout: 8, stride: 1, relu: true }, vec![INPUT]);
        let b = g.push("b", Op::Conv { kh: 1, kw: 1, cout: 8, stride: 1, relu: true }, vec![INPUT]);
        g.push("add", Op::Add, vec![a, b]);
        g.validate().unwrap();
    }

    #[test]
    #[should_panic(expected = "must agree")]
    fn add_shape_mismatch_panics() {
        let mut g = Graph::new("t", Shape::new(8, 8, 8));
        let a = g.push("a", Op::Conv { kh: 1, kw: 1, cout: 8, stride: 1, relu: true }, vec![INPUT]);
        let b = g.push("b", Op::Conv { kh: 1, kw: 1, cout: 16, stride: 1, relu: true }, vec![INPUT]);
        g.push("add", Op::Add, vec![a, b]);
    }

    #[test]
    fn same_padding_out_dims() {
        assert_eq!(out_dim(48, 3, 2), 24);
        assert_eq!(out_dim(47, 3, 2), 24);
        assert_eq!(out_dim(48, 3, 1), 48);
        assert_eq!(out_dim(48, 1, 1), 48);
        assert_eq!(out_dim(1, 3, 1), 1);
    }
}
