//! Architecture configuration — the paper's design knobs in one struct.
//!
//! The paper fixes the J3DAI point (§III-B3): "6 neural clusters of 16
//! computing blocks, each comprising 8 PEs. Thus, this configuration can
//! output a maximum of 768 MAC operations per clock cycle", 200 MHz,
//! 0.85 V, 28 nm FDSOI bottom/middle dies, 5 MB L2 (3 MB bottom + 2 MB
//! middle over 2048 data TSVs), DMPA moving 1024 bits/cycle vs the 64-bit
//! system-interconnect DMA. The scalability ablation sweeps these.

/// Full digital-system configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchConfig {
    /// Number of neural clusters (paper: 6).
    pub clusters: usize,
    /// Neural computing blocks per cluster (paper: 16).
    pub ncbs_per_cluster: usize,
    /// SIMD processing elements per NCB (paper: 8).
    pub pes_per_ncb: usize,
    /// Core clock in MHz (paper: 200).
    pub freq_mhz: f64,
    /// Logic supply voltage in volts (paper: 0.85).
    pub voltage: f64,
    /// Multi-banked SRAM per NCB, bytes (chosen: 16 KiB x 4 banks — the
    /// paper gives the *flattened, fully generic* multi-bank organization
    /// but not the size; 16 KiB/NCB puts 256 KiB per cluster, 1.5 MiB
    /// total accelerator-local SRAM, consistent with the 16 mm^2 budget).
    pub ncb_sram_bytes: usize,
    /// Independent SRAM banks inside one NCB.
    pub ncb_sram_banks: usize,
    /// L2 global memory on the bottom die, bytes (paper: 3 MB).
    pub l2_bottom_bytes: usize,
    /// L2 extension on the middle die, bytes (paper: 2 MB).
    pub l2_middle_bytes: usize,
    /// L2 is tiled in this many blocks of 64-bit words (paper: 16).
    pub l2_blocks: usize,
    /// DMPA column-connect width in bits per cycle (paper: 1024).
    pub dmpa_bits: usize,
    /// System interconnect (DMA) bus width in bits (paper: 64).
    pub dma_bus_bits: usize,
    /// Total middle<->bottom TSVs (paper: 3K, of which 2048 carry L2 data).
    pub tsv_total: usize,
    /// TSVs used for L2 data (1024 up + 1024 down).
    pub tsv_data: usize,
    /// Host CPU instruction/data memory, bytes (paper: 256 KB + 256 KB).
    pub host_imem_bytes: usize,
    pub host_dmem_bytes: usize,
    /// Fixed per-DMPA-transfer setup cycles (CCONNECT broadcast config).
    pub dmpa_setup_cycles: u64,
    /// Fixed per-DMA-descriptor setup cycles (bus arbitration + descriptor).
    pub dma_setup_cycles: u64,
    /// Per-macro-op controller overhead cycles (fetch/decode/AGU program).
    pub op_setup_cycles: u64,
    /// Extra per-op cycles when the AIU is disabled and routing must be
    /// configured with explicit instructions (the §III-B2 claim).
    pub route_cfg_cycles: u64,
    /// Per-compute-tile epilogue: accumulator drain through the requant
    /// write path, AGU/routing reconfiguration and bank-conflict stalls.
    /// Calibrated against Table I (EXPERIMENTS.md §Calibration).
    pub tile_epilogue_cycles: u64,
    /// Per-layer cross-cluster barrier + descriptor rearm, serial with
    /// compute. Calibrated against Table I (EXPERIMENTS.md §Calibration).
    pub layer_barrier_cycles: u64,
    /// Whether the Automatic Index Unit drives routing (paper: yes).
    pub aiu_enabled: bool,
    /// Whether the DMPA is available (ablation: fall back to DMA).
    pub dmpa_enabled: bool,
}

impl ArchConfig {
    /// The J3DAI design point from the paper.
    pub fn j3dai() -> Self {
        ArchConfig {
            clusters: 6,
            ncbs_per_cluster: 16,
            pes_per_ncb: 8,
            freq_mhz: 200.0,
            voltage: 0.85,
            ncb_sram_bytes: 16 * 1024,
            ncb_sram_banks: 4,
            l2_bottom_bytes: 3 * 1024 * 1024,
            l2_middle_bytes: 2 * 1024 * 1024,
            l2_blocks: 16,
            dmpa_bits: 1024,
            dma_bus_bits: 64,
            tsv_total: 3072,
            tsv_data: 2048,
            host_imem_bytes: 256 * 1024,
            host_dmem_bytes: 256 * 1024,
            dmpa_setup_cycles: 4,
            dma_setup_cycles: 16,
            op_setup_cycles: 6,
            route_cfg_cycles: 3,
            tile_epilogue_cycles: 575,
            layer_barrier_cycles: 2100,
            aiu_enabled: true,
            dmpa_enabled: true,
        }
    }

    /// Scalability variant: same microarchitecture, different array shape.
    pub fn scaled(clusters: usize, ncbs: usize, pes: usize) -> Self {
        ArchConfig { clusters, ncbs_per_cluster: ncbs, pes_per_ncb: pes, ..Self::j3dai() }
    }

    /// Peak MAC operations per clock cycle (paper: 768).
    pub fn macs_per_cycle(&self) -> u64 {
        (self.clusters * self.ncbs_per_cluster * self.pes_per_ncb) as u64
    }

    /// MACs per cycle available inside one cluster (paper: 128).
    pub fn cluster_macs_per_cycle(&self) -> u64 {
        (self.ncbs_per_cluster * self.pes_per_ncb) as u64
    }

    /// Total L2 capacity (paper: 5 MB).
    pub fn l2_bytes(&self) -> usize {
        self.l2_bottom_bytes + self.l2_middle_bytes
    }

    /// Accelerator-local SRAM across all NCBs.
    pub fn local_sram_bytes(&self) -> usize {
        self.clusters * self.ncbs_per_cluster * self.ncb_sram_bytes
    }

    /// NCB-local SRAM capacity of one cluster — the resident-buffer bound
    /// the mapper's tile search and the verifier's bounds pass share.
    pub fn cluster_local_bytes(&self) -> usize {
        self.ncbs_per_cluster * self.ncb_sram_bytes
    }

    /// Unified compiler-visible L2 arena: both L2 partitions plus the half
    /// of the NCB-local SRAM the placement stage may use as activation
    /// spill (`compiler::mapper::place_memory`'s capacity; the verifier
    /// checks every L2-side transfer window against this bound).
    pub fn l2_arena_bytes(&self) -> usize {
        self.l2_bytes() + self.local_sram_bytes() / 2
    }

    /// Peak throughput in GOPS (1 MAC = 2 ops).
    pub fn peak_gops(&self) -> f64 {
        self.macs_per_cycle() as f64 * 2.0 * self.freq_mhz * 1e6 / 1e9
    }

    /// Clock period in nanoseconds.
    pub fn clock_ns(&self) -> f64 {
        1e3 / self.freq_mhz
    }

    /// Cycles needed to move `bytes` through the DMPA column connect.
    pub fn dmpa_cycles(&self, bytes: u64) -> u64 {
        let per_cycle = (self.dmpa_bits / 8) as u64;
        self.dmpa_setup_cycles + bytes.div_ceil(per_cycle)
    }

    /// Cycles needed to move `bytes` over the 64-bit system interconnect.
    pub fn dma_cycles(&self, bytes: u64) -> u64 {
        let per_cycle = (self.dma_bus_bits / 8) as u64;
        self.dma_setup_cycles + bytes.div_ceil(per_cycle)
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.clusters >= 1 && self.clusters <= 64, "clusters out of range");
        anyhow::ensure!(self.ncbs_per_cluster >= 1, "need at least one NCB");
        anyhow::ensure!(self.pes_per_ncb >= 1, "need at least one PE");
        anyhow::ensure!(self.dmpa_bits % self.dma_bus_bits == 0, "DMPA width must be a multiple of the bus width");
        anyhow::ensure!(self.ncb_sram_bytes % self.ncb_sram_banks == 0, "SRAM must split evenly into banks");
        anyhow::ensure!(self.tsv_data <= self.tsv_total, "data TSVs exceed total TSVs");
        anyhow::ensure!(self.l2_blocks > 0 && self.l2_bytes() % self.l2_blocks == 0, "L2 must tile into blocks");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn j3dai_matches_paper_headline_numbers() {
        let c = ArchConfig::j3dai();
        assert_eq!(c.macs_per_cycle(), 768);
        assert_eq!(c.cluster_macs_per_cycle(), 128);
        assert_eq!(c.l2_bytes(), 5 * 1024 * 1024);
        assert!((c.peak_gops() - 307.2).abs() < 1e-9);
        c.validate().unwrap();
    }

    #[test]
    fn dmpa_is_16x_faster_than_dma_asymptotically() {
        // §III-B2: "DMPA enables the transfer of 1024 bits in a single clock
        // cycle, or 1 MB in 1000 clock cycles" vs the 64-bit DMA bus.
        let c = ArchConfig::j3dai();
        let mb = 1024 * 1024u64;
        let dmpa = c.dmpa_cycles(mb);
        let dma = c.dma_cycles(mb);
        assert_eq!(dmpa - c.dmpa_setup_cycles, 8192); // 1 MiB / 128 B
        // paper speaks of 1 MB = 10^6 bytes in "1000 cycles" order of magnitude
        assert!(dma / dmpa >= 15, "dma={dma} dmpa={dmpa}");
    }

    #[test]
    fn arena_and_cluster_bounds() {
        let c = ArchConfig::j3dai();
        assert_eq!(c.cluster_local_bytes(), 256 * 1024);
        assert_eq!(c.l2_arena_bytes(), c.l2_bytes() + c.local_sram_bytes() / 2);
    }

    #[test]
    fn scaled_configs_validate() {
        for cl in [1, 2, 4, 6, 8] {
            for nb in [4, 8, 16, 32] {
                ArchConfig::scaled(cl, nb, 8).validate().unwrap();
            }
        }
    }

    #[test]
    fn transfer_cycle_math_rounds_up() {
        let c = ArchConfig::j3dai();
        assert_eq!(c.dmpa_cycles(1), c.dmpa_setup_cycles + 1);
        assert_eq!(c.dmpa_cycles(128), c.dmpa_setup_cycles + 1);
        assert_eq!(c.dmpa_cycles(129), c.dmpa_setup_cycles + 2);
        assert_eq!(c.dma_cycles(8), c.dma_setup_cycles + 1);
        assert_eq!(c.dma_cycles(9), c.dma_setup_cycles + 2);
    }
}
