//! Sensor front-end model — the top/middle-die functions the AI die sees:
//! a 12-Mpixel Bayer array read out by the middle die, subsampled frames
//! pushed to the bottom die, full-resolution frames to the HSI.
//!
//! The paper's top die: 4096x3072 RGB, 4/3 aspect; the middle die readout
//! "transfers sub-sampled images to the third layer". We model the pixel
//! array synthetically (deterministic PRNG scene + moving gradient), a
//! 2x2-binning Bayer demosaic ISP, and the subsampling chain to the DNN
//! input resolutions (256x192 / 512x384).

use crate::graph::Shape;
use crate::quant::weights::SplitMix64;
use crate::sim::functional::Tensor;

/// Full sensor resolution (paper: 4096 x 3072 = 12 Mpixel).
pub const SENSOR_W: usize = 4096;
pub const SENSOR_H: usize = 3072;

/// Readout timing model (cycles at the middle-die clock per frame op).
#[derive(Debug, Clone, Copy)]
pub struct ReadoutTiming {
    /// Rows read per microsecond (rolling shutter).
    pub rows_per_us: f64,
    /// ISP pipeline latency per frame, microseconds.
    pub isp_latency_us: f64,
}

impl Default for ReadoutTiming {
    fn default() -> Self {
        // 3072 rows in ~8 ms -> 30 FPS with margin; subsampled reads skip rows.
        ReadoutTiming { rows_per_us: 400.0, isp_latency_us: 150.0 }
    }
}

impl ReadoutTiming {
    /// Time to deliver a subsampled frame of `rows` rows, microseconds.
    pub fn frame_time_us(&self, rows: usize) -> f64 {
        rows as f64 / self.rows_per_us + self.isp_latency_us
    }
}

/// A deterministic synthetic scene generator standing in for the pixel
/// matrix: a seeded noise field plus a per-frame moving gradient, so
/// downstream outputs change frame to frame but remain reproducible.
#[derive(Debug, Clone)]
pub struct PixelArray {
    seed: u64,
}

impl PixelArray {
    pub fn new(seed: u64) -> Self {
        PixelArray { seed }
    }

    /// Produce the subsampled RGB frame the middle die would push to the
    /// AI die: `shape` = (H, W, 3) in the DNN input domain.
    pub fn capture(&self, frame_idx: u64, shape: Shape) -> Tensor {
        assert_eq!(shape.c, 3, "sensor emits RGB");
        let mut rng = SplitMix64::new(self.seed ^ frame_idx.wrapping_mul(0x9E37_79B9));
        let mut data = vec![0u8; shape.elems()];
        // base noise (sensor readout + photon shot noise stand-in)
        for v in data.iter_mut() {
            *v = (rng.next_u64() >> 58) as u8; // 0..63 noise floor
        }
        // moving diagonal gradient = the "scene"
        let phase = (frame_idx % 255) as usize;
        for y in 0..shape.h {
            for x in 0..shape.w {
                let g = ((x + y + phase) * 255 / (shape.h + shape.w)) as u16;
                for c in 0..3 {
                    let i = (y * shape.w + x) * 3 + c;
                    let v = data[i] as u16 + g.saturating_sub(c as u16 * 17);
                    data[i] = v.min(255) as u8;
                }
            }
        }
        Tensor::new(shape, data)
    }
}

/// Subsample an RGB frame by integer binning (the ISP's decimation path).
pub fn subsample(src: &Tensor, factor: usize) -> Tensor {
    assert!(factor >= 1);
    let (h, w, c) = (src.shape.h / factor, src.shape.w / factor, src.shape.c);
    let mut data = vec![0u8; h * w * c];
    for y in 0..h {
        for x in 0..w {
            for ch in 0..c {
                // average the factor x factor bin
                let mut sum = 0u32;
                for dy in 0..factor {
                    for dx in 0..factor {
                        sum += src.data[((y * factor + dy) * src.shape.w + (x * factor + dx)) * c + ch] as u32;
                    }
                }
                data[(y * w + x) * c + ch] = (sum / (factor * factor) as u32) as u8;
            }
        }
    }
    Tensor::new(Shape::new(h, w, c), data)
}

/// High-speed-interface model: bytes and time to ship a full-res frame to
/// an external host (the paper's "transfer the full resolution image ...
/// when required" path — not used by the AI loop, but part of the system).
pub fn hsi_transfer_us(bytes: u64, gbps: f64) -> f64 {
    bytes as f64 * 8.0 / (gbps * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_are_deterministic_but_vary() {
        let p = PixelArray::new(42);
        let s = Shape::new(48, 64, 3);
        let f0 = p.capture(0, s);
        let f0b = p.capture(0, s);
        let f1 = p.capture(1, s);
        assert_eq!(f0.data, f0b.data);
        assert_ne!(f0.data, f1.data);
    }

    #[test]
    fn gradient_increases_along_diagonal() {
        let p = PixelArray::new(7);
        let f = p.capture(0, Shape::new(64, 64, 3));
        let lo = f.data[(0 * 64 + 0) * 3] as u32;
        let hi = f.data[(63 * 64 + 63) * 3] as u32;
        assert!(hi > lo, "hi={hi} lo={lo}");
    }

    #[test]
    fn subsample_halves_dims() {
        let p = PixelArray::new(1);
        let f = p.capture(0, Shape::new(96, 128, 3));
        let s = subsample(&f, 2);
        assert_eq!(s.shape, Shape::new(48, 64, 3));
    }

    #[test]
    fn readout_meets_30fps_at_dnn_resolution() {
        let t = ReadoutTiming::default();
        // 192 rows for the classifier input: well under the 33 ms budget
        assert!(t.frame_time_us(192) < 33_000.0);
        // even the 384-row segmentation input fits a 7.43 ms + readout frame
        assert!(t.frame_time_us(384) < 5_000.0);
    }

    #[test]
    fn hsi_full_frame_time() {
        // 12 Mpixel RGB ~ 36 MB at 10 Gbps ~ 28.8 ms — why full-res frames
        // go out only "when required" while AI runs on subsampled input.
        let us = hsi_transfer_us((SENSOR_W * SENSOR_H * 3) as u64, 10.0);
        assert!(us > 20_000.0 && us < 40_000.0, "us={us}");
    }
}
