//! Table/figure rendering — formats measurements as the paper prints them,
//! plus the telemetry views: the per-layer breakdown table behind
//! `j3dai trace`, the roofline analysis behind `j3dai roofline`, and the
//! machine-readable `BENCH_telemetry.json` / `BENCH_ppa.json` files.

use crate::config::ArchConfig;
use crate::graph::Graph;
use crate::power::{area, EnergyModel};
use crate::sim::{SimResult, SimTrace};
use crate::telemetry::pmu::{PmuBank, STALL_REASONS};
use crate::telemetry::{self, json, EnergyBreakdown};

pub mod compare;

fn opt_json(v: Option<f64>) -> String {
    v.map(json::fmt_f64).unwrap_or_else(|| "null".into())
}

/// One column of Table I.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub model: String,
    pub mmacs: f64,
    pub input: String,
    pub latency_ms: f64,
    pub power_mw_30: Option<f64>,
    pub power_mw_200: Option<f64>,
    pub tops_per_w: Option<f64>,
    pub mac_eff: f64,
}

/// Build a Table I row from a simulation result.
pub fn table1_row(r: &SimResult, em: &EnergyModel, input: &str) -> Table1Row {
    Table1Row {
        model: r.model.clone(),
        mmacs: r.total_macs as f64 / 1e6,
        input: input.to_string(),
        latency_ms: r.latency_ms,
        power_mw_30: r.power_mw(em, 30.0),
        power_mw_200: r.power_mw(em, 200.0),
        tops_per_w: r.tops_per_watt(em, 200.0).or_else(|| r.tops_per_watt(em, 30.0)),
        mac_eff: r.mac_efficiency,
    }
}

fn opt(v: Option<f64>, prec: usize) -> String {
    v.map(|x| format!("{x:.prec$}")).unwrap_or_else(|| "-".into())
}

/// Render Table I next to the paper's reported values.
pub fn render_table1(rows: &[Table1Row]) -> String {
    let paper: &[(&str, f64, f64, &str, &str, f64, f64)] = &[
        // (model key, MMACs, latency, P@30, P@200, TOPs/W, eff%)
        ("mbv1", 557.0, 4.96, "47.6", "291.2", 0.77, 76.8),
        ("mbv2", 289.0, 4.04, "30.5", "186.7", 0.62, 46.6),
        ("fpnseg", 877.0, 7.43, "63.8", "-", 0.82, 76.5),
    ];
    let mut s = String::new();
    s.push_str("TABLE I: Key performance metrics of selected models (measured vs paper)\n");
    s.push_str(&format!(
        "{:<14} {:>8} {:>9} {:>12} {:>12} {:>12} {:>12} {:>10}\n",
        "Model", "MMACs", "Input", "Lat ms", "P@30 mW", "P@200 mW", "TOPs/W", "MAC eff %"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<14} {:>8.0} {:>9} {:>12.2} {:>12} {:>12} {:>12} {:>10.1}\n",
            r.model,
            r.mmacs,
            r.input,
            r.latency_ms,
            opt(r.power_mw_30, 1),
            opt(r.power_mw_200, 1),
            opt(r.tops_per_w, 2),
            r.mac_eff * 100.0
        ));
        if let Some(p) = paper.iter().find(|p| r.model.starts_with(p.0)) {
            s.push_str(&format!(
                "{:<14} {:>8.0} {:>9} {:>12.2} {:>12} {:>12} {:>12.2} {:>10.1}   <- paper\n",
                "  (paper)", p.1, "-", p.2, p.3, p.4, p.5, p.6
            ));
        }
    }
    s
}

/// One column of Table II.
#[derive(Debug, Clone)]
pub struct Table2Col {
    pub label: String,
    pub process: String,
    pub chip_mm2: f64,
    pub dnn_mem_mm2: f64,
    pub pixels: String,
    pub clock_mhz: f64,
    pub macs: u64,
    pub mac_eff_pct: f64,
    pub power_mw_200fps: Option<f64>,
    pub time_ms_262: Option<f64>,
    pub tops_per_w: Option<f64>,
}

impl Table2Col {
    /// GOPS/W/mm^2 — TOPS/W over full (stacked) chip area, x1000.
    pub fn gops_w_mm2(&self) -> Option<f64> {
        self.tops_per_w.map(|t| t * 1000.0 / self.chip_mm2)
    }
}

/// The two SONY comparison columns with the paper's reported values.
pub fn sony_columns() -> Vec<Table2Col> {
    vec![
        Table2Col {
            label: "SONY ISSCC'21".into(),
            process: "65nm / n.a. / 22nm".into(),
            chip_mm2: 124.0,
            dnn_mem_mm2: 31.0,
            pixels: "4056x3040".into(),
            clock_mhz: 262.5,
            macs: 2304,
            mac_eff_pct: 13.4,
            power_mw_200fps: Some(122.5),
            time_ms_262: Some(3.70),
            tops_per_w: Some(0.98),
        },
        Table2Col {
            label: "SONY IEDM'24".into(),
            process: "65nm / 40nm / 22nm".into(),
            chip_mm2: 262.0,
            dnn_mem_mm2: 87.0,
            pixels: "8784x6096".into(),
            clock_mhz: 219.6,
            macs: 1024,
            mac_eff_pct: 59.9,
            power_mw_200fps: Some(90.4),
            time_ms_262: Some(1.87),
            tops_per_w: Some(1.33),
        },
    ]
}

/// Build the J3DAI column from our MobileNetV2 simulation (the table's
/// starred remark: all DNN-system rows are MobileNetV2).
pub fn j3dai_column(cfg: &ArchConfig, mbv2: &SimResult, em: &EnergyModel) -> Table2Col {
    // "Processing time @262.5 MHz": latency rescaled to the common clock.
    let time_262 = mbv2.latency_ms * cfg.freq_mhz / 262.5;
    Table2Col {
        label: "J3DAI (this work)".into(),
        process: "40nm / 28nm / 28nm".into(),
        chip_mm2: 3.0 * area::DIE_H_MM * area::DIE_V_MM,
        dnn_mem_mm2: area::DIE_H_MM * area::DIE_V_MM,
        pixels: "4096x3072".into(),
        clock_mhz: cfg.freq_mhz,
        macs: cfg.macs_per_cycle(),
        mac_eff_pct: mbv2.mac_efficiency * 100.0,
        power_mw_200fps: mbv2.power_mw(em, 200.0),
        time_ms_262: Some(time_262),
        tops_per_w: mbv2.tops_per_watt(em, 200.0),
    }
}

/// Render Table II.
pub fn render_table2(cols: &[Table2Col]) -> String {
    let mut s = String::new();
    s.push_str("TABLE II: Comparison with prior stacked-sensor DNN systems (MobileNetV2)\n");
    let row = |name: &str, f: &dyn Fn(&Table2Col) -> String| {
        let mut line = format!("{name:<34}");
        for c in cols {
            line.push_str(&format!(" {:>22}", f(c)));
        }
        line.push('\n');
        line
    };
    s.push_str(&row("", &|c| c.label.clone()));
    s.push_str(&row("Process (T/M/B)", &|c| c.process.clone()));
    s.push_str(&row("Chip size [mm2, stacked]", &|c| format!("{:.1}", c.chip_mm2)));
    s.push_str(&row("DNN+memory size [mm2]", &|c| format!("{:.1}", c.dnn_mem_mm2)));
    s.push_str(&row("Effective pixels", &|c| c.pixels.clone()));
    s.push_str(&row("Processor clock [MHz]", &|c| format!("{:.1}", c.clock_mhz)));
    s.push_str(&row("Number of MACs", &|c| c.macs.to_string()));
    s.push_str(&row("MAC efficiency [%]", &|c| format!("{:.1}", c.mac_eff_pct)));
    s.push_str(&row("Power @200fps [mW]", &|c| opt(c.power_mw_200fps, 1)));
    s.push_str(&row("Time @262.5MHz [ms]", &|c| opt(c.time_ms_262, 2)));
    s.push_str(&row("Power efficiency [TOPS/W]", &|c| opt(c.tops_per_w, 2)));
    s.push_str(&row("Energy eff./area [GOPS/W/mm2]", &|c| opt(c.gops_w_mm2(), 1)));
    s
}

/// Render a die floorplan as the Fig. 5 stand-in.
pub fn render_floorplan(plan: &area::DiePlan) -> String {
    let mut s = format!(
        "Fig.5 {} — outline {:.2} mm^2, used {:.2} mm^2 ({:.0}% util)\n",
        plan.name,
        plan.outline_mm2,
        plan.used_mm2(),
        plan.utilization() * 100.0
    );
    for r in &plan.regions {
        let bar = "#".repeat(((r.mm2 / plan.outline_mm2) * 60.0).round() as usize);
        s.push_str(&format!("  {:<28} {:>6.2} mm^2 |{}\n", r.name, r.mm2, bar));
    }
    s
}

/// Render the Fig. 6 at-scale chip comparison.
pub fn render_fig6() -> String {
    let chips = area::fig6_chips();
    let max_h = chips.iter().map(|c| c.h_mm).fold(0.0, f64::max);
    let mut s = String::from("Fig.6 chip-size comparison (1 char ~ 0.5 mm)\n");
    for c in &chips {
        let w = (c.h_mm * 2.0).round() as usize;
        let h = ((c.v_mm * 2.0) / 2.0).round() as usize; // terminal aspect
        s.push_str(&format!(
            "{} — {:.3} x {:.3} mm = {:.1} mm^2/die x {} layers = {:.0} mm^2\n",
            c.label,
            c.h_mm,
            c.v_mm,
            c.area_mm2(),
            c.layers,
            c.area_mm2() * c.layers as f64
        ));
        for _ in 0..h.max(1) {
            s.push_str(&format!("  {}\n", "█".repeat(w.max(1))));
        }
        let _ = max_h;
    }
    s
}

/// Terminal per-layer breakdown of a traced simulation: where the cycles,
/// stalls, bytes, MAC efficiency — and now energy and arithmetic
/// intensity — go, layer by layer.
pub fn render_layer_table(tr: &SimTrace) -> String {
    let mut s = format!(
        "Per-layer breakdown — {} @ {:.0} MHz ({} layers)\n",
        tr.model,
        1e3 / tr.clock_ns,
        tr.layers.len()
    );
    s.push_str(&format!(
        "{:<4} {:<16} {:>10} {:>10} {:>10} {:>10} {:>12} {:>12} {:>9} {:>9} {:>8} {:>18}\n",
        "#",
        "Layer",
        "Cycles",
        "Comp busy",
        "Xfer busy",
        "Stall",
        "MACs",
        "Bytes",
        "Eff %",
        "E mJ",
        "MACs/B",
        "Top stall"
    ));
    let (mut cyc, mut stall, mut macs, mut bytes) = (0u64, 0u64, 0u64, 0u64);
    let mut energy = 0.0f64;
    for l in &tr.layers {
        s.push_str(&format!(
            "{:<4} {:<16} {:>10} {:>10} {:>10} {:>10} {:>12} {:>12} {:>9.1} {:>9.4} {:>8.1} \
             {:>18}\n",
            l.layer,
            l.name,
            l.cycles,
            l.compute_busy,
            l.xfer_busy,
            l.stall_cycles,
            l.macs,
            l.bytes,
            l.mac_efficiency * 100.0,
            l.energy_mj,
            l.arith_intensity,
            stall_mix(&l.stall_breakdown)
        ));
        cyc += l.cycles;
        stall += l.stall_cycles;
        macs += l.macs;
        bytes += l.bytes;
        energy += l.energy_mj;
    }
    s.push_str(&format!(
        "{:<4} {:<16} {:>10} {:>10} {:>10} {:>10} {:>12} {:>12} {:>9} {:>9.4}\n",
        "", "total", cyc, "", "", stall, macs, bytes, "", energy
    ));
    s
}

/// "reason pct%" summary of a stall-cycle array (the dominant reason), or
/// "-" when nothing stalled.
fn stall_mix(stalls: &[u64]) -> String {
    let total: u64 = stalls.iter().sum();
    if total == 0 {
        return "-".into();
    }
    let (i, top) = stalls.iter().enumerate().max_by_key(|(_, v)| **v).unwrap();
    format!("{} {:.0}%", STALL_REASONS[i].label(), *top as f64 / total as f64 * 100.0)
}

/// Per-layer PMU stall attribution plus the per-cluster accounting proof:
/// every simulated cycle is busy, control, or a classified stall — the
/// `j3dai sim` command prints this below the per-model summary.
pub fn render_stall_table(g: &Graph, r: &SimResult) -> String {
    // sum the per-layer banks across clusters
    let mut layers: std::collections::BTreeMap<u32, PmuBank> = std::collections::BTreeMap::new();
    for c in &r.clusters {
        for (li, bank) in &c.pmu.per_layer {
            layers.entry(*li).or_default().merge(bank);
        }
    }
    let mut s = format!("Stall attribution — {} ({} clusters)\n", r.model, r.clusters.len());
    s.push_str(&format!(
        "{:<4} {:<16} {:>10} {:>8} {:>10} {:>10} {:>10} {:>13}\n",
        "#", "Layer", "Busy", "Ctrl", "dma_wait", "ncb_arb", "l2_bank", "weight_refill"
    ));
    let mut total = PmuBank::default();
    for (li, bank) in &layers {
        let name = g.layers.get(*li as usize).map(|l| l.name.as_str()).unwrap_or("setup");
        let st = bank.stalls;
        s.push_str(&format!(
            "{:<4} {:<16} {:>10} {:>8} {:>10} {:>10} {:>10} {:>13}\n",
            li, name, bank.busy, bank.ctrl, st[0], st[1], st[2], st[3]
        ));
        total.merge(bank);
    }
    let ts = total.stalls;
    s.push_str(&format!(
        "{:<4} {:<16} {:>10} {:>8} {:>10} {:>10} {:>10} {:>13}\n",
        "", "total", total.busy, total.ctrl, ts[0], ts[1], ts[2], ts[3]
    ));
    // per-cluster accounting: busy + ctrl + classified stalls (including
    // the system-level host_sync fold) must cover every simulated cycle
    for (ci, c) in r.clusters.iter().enumerate() {
        let b = &c.pmu.total;
        let ok = if b.accounted() == r.cycles { "OK" } else { "MISMATCH" };
        s.push_str(&format!("cluster {ci}: busy {} ctrl {}", b.busy, b.ctrl));
        for (reason, v) in STALL_REASONS.iter().zip(b.stalls) {
            s.push_str(&format!(" {} {}", reason.label(), v));
        }
        s.push_str(&format!(" -> {} of {} [{}]\n", b.accounted(), r.cycles, ok));
    }
    s
}

/// Per-cluster utilization/stall/energy summary of one simulated inference
/// — the per-cluster energy split next to the PMU view.
pub fn render_cluster_table(r: &SimResult, em: &EnergyModel) -> String {
    let mut s = format!("Per-cluster breakdown — {} ({} cycles)\n", r.model, r.cycles);
    s.push_str(&format!(
        "{:<8} {:>10} {:>10} {:>10} {:>7} {:>10} {:>20} {:>9}\n",
        "Cluster", "Cycles", "Comp busy", "Xfer busy", "Util %", "Stall", "Top stall", "E mJ"
    ));
    let mut energy = 0.0f64;
    for (ci, c) in r.clusters.iter().enumerate() {
        let mj = EnergyBreakdown::from_activity(em, &c.activity).total_mj();
        energy += mj;
        s.push_str(&format!(
            "{:<8} {:>10} {:>10} {:>10} {:>7.1} {:>10} {:>20} {:>9.4}\n",
            ci,
            c.cycles,
            c.compute_busy,
            c.xfer_busy,
            c.compute_busy as f64 / r.cycles as f64 * 100.0,
            c.pmu.total.stall_total(),
            stall_mix(&c.pmu.total.stalls),
            mj
        ));
    }
    s.push_str(&format!(
        "{:<8} {:>10} {:>10} {:>10} {:>7} {:>10} {:>20} {:>9.4}\n",
        "total", r.cycles, "", "", "", "", "", energy
    ));
    s
}

/// One layer's position on the roofline: arithmetic intensity on the x
/// axis, achieved GOPS on the y axis, the attainable ceiling, and whether
/// the layer sits under the bandwidth slope (memory-bound) or the flat
/// peak-MAC roof (compute-bound).
#[derive(Debug, Clone)]
pub struct RooflinePoint {
    pub layer: usize,
    pub name: String,
    /// MACs per off-cluster (DMPA + DMA) byte.
    pub intensity: f64,
    /// Throughput actually sustained across the layer extent, GOPS.
    pub achieved_gops: f64,
    /// `min(peak, 2 * intensity * bandwidth)` for the layer's dominant
    /// transfer path, GOPS.
    pub attainable_gops: f64,
    /// The bandwidth ceiling used for this layer, GB/s.
    pub bw_gbs: f64,
    /// True when the bandwidth slope (not the MAC roof) caps the layer.
    pub memory_bound: bool,
}

/// Sustained DMPA bandwidth, GB/s.
pub fn dmpa_bw_gbs(cfg: &ArchConfig) -> f64 {
    (cfg.dmpa_bits / 8) as f64 * cfg.freq_mhz * 1e6 / 1e9
}

/// Sustained system-interconnect DMA bandwidth, GB/s.
pub fn dma_bw_gbs(cfg: &ArchConfig) -> f64 {
    (cfg.dma_bus_bits / 8) as f64 * cfg.freq_mhz * 1e6 / 1e9
}

/// Place every traced layer on the roofline. The bandwidth ceiling per
/// layer follows its dominant off-cluster path: layers fed by the DMPA get
/// the wide column-connect slope, DMA-fed layers the narrow 64-bit bus.
pub fn roofline_points(tr: &SimTrace, cfg: &ArchConfig) -> Vec<RooflinePoint> {
    let peak = cfg.peak_gops();
    tr.layers
        .iter()
        .map(|l| {
            let bw = if l.activity.dmpa_bytes >= l.activity.dma_bytes && cfg.dmpa_enabled {
                dmpa_bw_gbs(cfg)
            } else {
                dma_bw_gbs(cfg)
            };
            // ops/byte = 2 * MACs/byte (1 MAC = 2 ops, the paper's GOPS unit)
            let slope = 2.0 * l.arith_intensity * bw;
            let attainable = slope.min(peak);
            RooflinePoint {
                layer: l.layer,
                name: l.name.clone(),
                intensity: l.arith_intensity,
                achieved_gops: l.achieved_gops,
                attainable_gops: attainable,
                bw_gbs: bw,
                memory_bound: slope < peak,
            }
        })
        .collect()
}

/// Render the roofline report: the machine ceilings, the ridge points, and
/// one row per layer with its bound classification.
pub fn render_roofline(tr: &SimTrace, cfg: &ArchConfig) -> String {
    let peak = cfg.peak_gops();
    let (dmpa_bw, dma_bw) = (dmpa_bw_gbs(cfg), dma_bw_gbs(cfg));
    let pts = roofline_points(tr, cfg);
    let mut s = format!(
        "Roofline — {} on {} MAC/cycle @ {:.0} MHz (peak {:.1} GOPS)\n",
        tr.model,
        cfg.macs_per_cycle(),
        cfg.freq_mhz,
        peak
    );
    s.push_str(&format!(
        "ceilings: DMPA {:.1} GB/s (ridge {:.1} MACs/B), DMA {:.1} GB/s (ridge {:.1} MACs/B)\n",
        dmpa_bw,
        peak / (2.0 * dmpa_bw),
        dma_bw,
        peak / (2.0 * dma_bw)
    ));
    s.push_str(&format!(
        "{:<4} {:<16} {:>9} {:>12} {:>13} {:>9} {:>8}  bound\n",
        "#", "Layer", "MACs/B", "GOPS", "ceiling GOPS", "% of cap", "BW GB/s"
    ));
    let mut mem_bound = 0usize;
    for p in &pts {
        let pct = if p.attainable_gops > 0.0 {
            p.achieved_gops / p.attainable_gops * 100.0
        } else {
            0.0
        };
        s.push_str(&format!(
            "{:<4} {:<16} {:>9.1} {:>12.1} {:>13.1} {:>9.0} {:>8.1}  {}\n",
            p.layer,
            p.name,
            p.intensity,
            p.achieved_gops,
            p.attainable_gops,
            pct,
            p.bw_gbs,
            if p.memory_bound { "MEMORY" } else { "compute" }
        ));
        mem_bound += usize::from(p.memory_bound);
    }
    s.push_str(&format!(
        "{} of {} layers memory-bound (ceiling set by transfer bandwidth, not the MAC array)\n",
        mem_bound,
        pts.len()
    ));
    s
}

/// Hand-written, dependency-free roofline SVG: log-log axes with decade
/// gridlines, the flat peak-MAC roof, the DMPA and DMA bandwidth slopes,
/// and one circle per layer with memory-bound layers highlighted
/// (`j3dai roofline --svg-out`).
pub fn roofline_svg(tr: &SimTrace, cfg: &ArchConfig) -> String {
    let peak = cfg.peak_gops();
    let pts = roofline_points(tr, cfg);
    let (w, h) = (800.0, 520.0);
    let (ml, mr, mt, mb) = (70.0, 25.0, 35.0, 55.0);
    let (pw, ph) = (w - ml - mr, h - mt - mb);

    // whole-decade log ranges covering every layer point and both ridges
    let mut xmax = peak / (2.0 * dma_bw_gbs(cfg));
    let mut xmin = 0.1f64;
    let mut ymin = peak;
    for p in &pts {
        xmin = xmin.min(p.intensity.max(1e-2));
        xmax = xmax.max(p.intensity);
        ymin = ymin.min(p.achieved_gops.max(1e-2));
    }
    let x0 = xmin.log10().floor();
    let x1 = xmax.log10().ceil().max(x0 + 1.0);
    let y0 = ymin.log10().floor();
    let y1 = peak.log10().ceil().max(y0 + 1.0);
    let sx = |x: f64| ml + (x.max(1e-12).log10() - x0) / (x1 - x0) * pw;
    let sy = |y: f64| mt + ph - (y.max(1e-12).log10() - y0) / (y1 - y0) * ph;

    let mut s = format!(
        "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{w}\" height=\"{h}\" \
         viewBox=\"0 0 {w} {h}\" font-family=\"monospace\" font-size=\"12\">\n"
    );
    s.push_str("<rect width=\"100%\" height=\"100%\" fill=\"white\"/>\n");
    s.push_str(&format!(
        "<text x=\"{ml}\" y=\"20\" font-size=\"14\">Roofline — {} (peak {:.1} GOPS)</text>\n",
        tr.model, peak
    ));

    // decade gridlines + tick labels
    for d in (x0 as i32)..=(x1 as i32) {
        let v = 10f64.powi(d);
        let x = sx(v);
        s.push_str(&format!(
            "<line x1=\"{x:.1}\" y1=\"{mt}\" x2=\"{x:.1}\" y2=\"{:.1}\" stroke=\"#ddd\"/>\n",
            mt + ph
        ));
        s.push_str(&format!(
            "<text x=\"{x:.1}\" y=\"{:.1}\" text-anchor=\"middle\">{v}</text>\n",
            mt + ph + 18.0
        ));
    }
    for d in (y0 as i32)..=(y1 as i32) {
        let v = 10f64.powi(d);
        let y = sy(v);
        s.push_str(&format!(
            "<line x1=\"{ml}\" y1=\"{y:.1}\" x2=\"{:.1}\" y2=\"{y:.1}\" stroke=\"#ddd\"/>\n",
            ml + pw
        ));
        s.push_str(&format!(
            "<text x=\"{:.1}\" y=\"{:.1}\" text-anchor=\"end\">{v}</text>\n",
            ml - 6.0,
            y + 4.0
        ));
    }
    s.push_str(&format!(
        "<text x=\"{:.1}\" y=\"{:.1}\" text-anchor=\"middle\">arithmetic intensity \
         [MACs/byte]</text>\n",
        ml + pw / 2.0,
        h - 12.0
    ));
    s.push_str(&format!(
        "<text x=\"18\" y=\"{:.1}\" transform=\"rotate(-90 18 {:.1})\" \
         text-anchor=\"middle\">achieved [GOPS]</text>\n",
        mt + ph / 2.0,
        mt + ph / 2.0
    ));

    // flat peak roof across the plot, then one slope per bandwidth ceiling
    s.push_str(&format!(
        "<line x1=\"{ml}\" y1=\"{:.1}\" x2=\"{:.1}\" y2=\"{:.1}\" stroke=\"#333\" \
         stroke-width=\"1.5\"/>\n",
        sy(peak),
        ml + pw,
        sy(peak)
    ));
    let slopes = [(dmpa_bw_gbs(cfg), "#2ca02c", "DMPA"), (dma_bw_gbs(cfg), "#9467bd", "DMA")];
    for (bw, color, label) in slopes {
        // clip the slope's start so it enters the plot at the bottom decade
        let xl = 10f64.powf(x0).max(10f64.powf(y0) / (2.0 * bw));
        let ridge = (peak / (2.0 * bw)).max(xl);
        s.push_str(&format!(
            "<line x1=\"{:.1}\" y1=\"{:.1}\" x2=\"{:.1}\" y2=\"{:.1}\" stroke=\"{}\" \
             stroke-width=\"1.5\"/>\n",
            sx(xl),
            sy((2.0 * xl * bw).min(peak)),
            sx(ridge),
            sy(peak),
            color
        ));
        s.push_str(&format!(
            "<text x=\"{:.1}\" y=\"{:.1}\" fill=\"{}\">{} {:.1} GB/s</text>\n",
            sx(ridge) + 4.0,
            sy(peak) + 14.0,
            color,
            label,
            bw
        ));
    }

    // one circle per layer, hover title with the numbers behind it
    for p in &pts {
        let fill = if p.memory_bound { "#d62728" } else { "#1f77b4" };
        let bound = if p.memory_bound { "memory-bound" } else { "compute-bound" };
        s.push_str(&format!(
            "<circle cx=\"{:.1}\" cy=\"{:.1}\" r=\"5\" fill=\"{}\" fill-opacity=\"0.8\">\
             <title>{}: {:.1} MACs/B, {:.1} GOPS ({})</title></circle>\n",
            sx(p.intensity.max(1e-2)),
            sy(p.achieved_gops.max(1e-2)),
            fill,
            p.name,
            p.intensity,
            p.achieved_gops,
            bound
        ));
    }

    // legend
    let lx = ml + pw - 170.0;
    s.push_str(&format!("<circle cx=\"{lx:.1}\" cy=\"48\" r=\"5\" fill=\"#d62728\"/>\n"));
    s.push_str(&format!("<text x=\"{:.1}\" y=\"52\">memory-bound</text>\n", lx + 10.0));
    s.push_str(&format!("<circle cx=\"{lx:.1}\" cy=\"66\" r=\"5\" fill=\"#1f77b4\"/>\n"));
    s.push_str(&format!("<text x=\"{:.1}\" y=\"70\">compute-bound</text>\n", lx + 10.0));
    s.push_str("</svg>\n");
    s
}

/// One model's entry in `BENCH_ppa.json` — the paper's PPA triple (power,
/// performance, area) plus the energy figures behind it.
#[derive(Debug, Clone)]
pub struct PpaEntry {
    pub model: String,
    pub mmacs: f64,
    pub latency_ms: f64,
    /// Dynamic energy of one inference, mJ.
    pub energy_mj: f64,
    pub power_mw_30: Option<f64>,
    /// None when the latency cannot sustain 200 FPS (paper prints "-").
    pub power_mw_200: Option<f64>,
    pub tops_per_w: Option<f64>,
    pub mac_eff: f64,
    pub max_fps: f64,
}

/// Build a PPA entry from a simulation result.
pub fn ppa_entry(r: &SimResult, em: &EnergyModel) -> PpaEntry {
    PpaEntry {
        model: r.model.clone(),
        mmacs: r.total_macs as f64 / 1e6,
        latency_ms: r.latency_ms,
        energy_mj: em.inference_mj(&r.activity),
        power_mw_30: r.power_mw(em, 30.0),
        power_mw_200: r.power_mw(em, 200.0),
        tops_per_w: r.tops_per_watt(em, 200.0).or_else(|| r.tops_per_watt(em, 30.0)),
        mac_eff: r.mac_efficiency,
        max_fps: r.max_fps,
    }
}

/// Render `BENCH_ppa.json`: the arch header (area comes from the die plan,
/// matching Table II's chip-size rows) plus one entry per model. The
/// `tests/ppa_regression.rs` gate re-parses this format.
pub fn bench_ppa_json(cfg: &ArchConfig, entries: &[PpaEntry]) -> String {
    let die_mm2 = area::DIE_H_MM * area::DIE_V_MM;
    let mut s = String::from("{\n  \"arch\": {");
    s.push_str(&format!(
        "\"clusters\": {}, \"macs_per_cycle\": {}, \"freq_mhz\": {}, \"peak_gops\": {}, \
         \"die_mm2\": {}, \"stacked_mm2\": {}",
        cfg.clusters,
        cfg.macs_per_cycle(),
        json::fmt_f64(cfg.freq_mhz),
        json::fmt_f64(cfg.peak_gops()),
        json::fmt_f64(die_mm2),
        json::fmt_f64(3.0 * die_mm2),
    ));
    s.push_str("},\n  \"models\": [");
    for (i, e) in entries.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "\n    {{\"model\": \"{}\", \"mmacs\": {}, \"latency_ms\": {}, \"energy_mj\": {}, \
             \"power_mw_30\": {}, \"power_mw_200\": {}, \"tops_per_w\": {}, \"mac_eff\": {}, \
             \"max_fps\": {}}}",
            json::escape(&e.model),
            json::fmt_f64(e.mmacs),
            json::fmt_f64(e.latency_ms),
            json::fmt_f64(e.energy_mj),
            opt_json(e.power_mw_30),
            opt_json(e.power_mw_200),
            opt_json(e.tops_per_w),
            json::fmt_f64(e.mac_eff),
            json::fmt_f64(e.max_fps),
        ));
    }
    s.push_str("\n  ]\n}\n");
    s
}

/// One model's entry for `BENCH_telemetry.json`.
#[derive(Debug, Clone)]
pub struct BenchEntry {
    pub model: String,
    /// Modeled inference latency (cycle simulator), ms.
    pub latency_ms: f64,
    /// MAC/cycle efficiency of the modeled run.
    pub mac_eff: f64,
    /// Wall-clock of untraced `simulate` runs, ms.
    pub plain_wall_ms: Vec<f64>,
    /// Wall-clock of traced `simulate_traced` runs, ms.
    pub traced_wall_ms: Vec<f64>,
}

/// Render the machine-readable benchmark file: per-model modeled numbers
/// plus the tracing overhead (p50 traced vs p50 plain wall time). Uses the
/// shared [`telemetry::percentile`] helper.
pub fn bench_telemetry_json(entries: &[BenchEntry]) -> String {
    let p50 = |samples: &[f64]| {
        let mut v = samples.to_vec();
        telemetry::percentile_unsorted(&mut v, 50.0)
    };
    let mut s = String::from("{\n  \"benchmarks\": [");
    for (i, e) in entries.iter().enumerate() {
        let plain = p50(&e.plain_wall_ms);
        let traced = p50(&e.traced_wall_ms);
        let overhead_pct = if plain.is_finite() && plain > 0.0 && traced.is_finite() {
            (traced / plain - 1.0) * 100.0
        } else {
            0.0
        };
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "\n    {{\"model\": \"{}\", \"latency_ms\": {}, \"mac_eff\": {}, \
             \"sim_wall_ms_p50\": {}, \"traced_wall_ms_p50\": {}, \"trace_overhead_pct\": {}}}",
            json::escape(&e.model),
            json::fmt_f64(e.latency_ms),
            json::fmt_f64(e.mac_eff),
            json::fmt_f64(plain),
            json::fmt_f64(traced),
            json::fmt_f64(overhead_pct),
        ));
    }
    s.push_str("\n  ]\n}\n");
    s
}

/// One model's entry for `BENCH_throughput.json` (`j3dai bench-throughput`).
#[derive(Debug, Clone)]
pub struct ThroughputEntry {
    /// Paper workload name (e.g. `fpnseg_1_2`).
    pub model: String,
    /// Artifact twin the frame pipeline ran (e.g. `fpnseg_w25_48x64`).
    pub twin: String,
    /// Min wall-clock of the cycle simulation at 1 thread, ms.
    pub sim_wall_ms_1t: f64,
    /// Min wall-clock at the benchmarked thread count, ms.
    pub sim_wall_ms_nt: f64,
    /// `sim_wall_ms_1t / sim_wall_ms_nt` — scale-invariant, the gated metric.
    pub speedup: f64,
    /// End-to-end frames/s of the multi-worker functional pipeline.
    pub frames_per_s: f64,
    /// Frames the pipeline processed for the fps figure.
    pub frames: u64,
}

/// Render the machine-readable throughput benchmark file. The `"bench":
/// "throughput"` tag is how `bench-compare` tells this format apart from
/// `bench-ppa` output; [`compare::parse_bench_throughput`] re-parses it.
pub fn bench_throughput_json(
    threads: usize,
    workers: usize,
    iters: usize,
    entries: &[ThroughputEntry],
) -> String {
    let mut s = format!(
        "{{\n  \"bench\": \"throughput\",\n  \"threads\": {threads},\n  \
         \"workers\": {workers},\n  \"iters\": {iters},\n  \"models\": ["
    );
    for (i, e) in entries.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "\n    {{\"model\": \"{}\", \"twin\": \"{}\", \"sim_wall_ms_1t\": {}, \
             \"sim_wall_ms_nt\": {}, \"speedup\": {}, \"frames_per_s\": {}, \"frames\": {}}}",
            json::escape(&e.model),
            json::escape(&e.twin),
            json::fmt_f64(e.sim_wall_ms_1t),
            json::fmt_f64(e.sim_wall_ms_nt),
            json::fmt_f64(e.speedup),
            json::fmt_f64(e.frames_per_s),
            e.frames,
        ));
    }
    s.push_str("\n  ]\n}\n");
    s
}

/// Render the `lint` subcommand's human-readable diagnostics table for
/// one verified model: summary line, fixed-width columns, then (when the
/// policy captured any) the listing context of each error.
pub fn render_diagnostics(model: &str, report: &crate::verify::VerifyReport) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{model}: {} error(s), {} warning(s), {} note(s)\n",
        report.error_count(),
        report.warning_count(),
        report.note_count()
    ));
    if report.diagnostics.is_empty() {
        s.push_str("  clean — no diagnostics\n");
        return s;
    }
    s.push_str(&format!(
        "  {:<8} {:<9} {:<28} {:>7} {:>6}  {}\n",
        "severity", "pass", "rule", "cluster", "pc", "message"
    ));
    for d in &report.diagnostics {
        s.push_str(&format!(
            "  {:<8} {:<9} {:<28} {:>7} {:>6}  {}\n",
            d.severity.label(),
            d.pass.label(),
            d.code,
            d.cluster,
            d.pc,
            d.message
        ));
    }
    for d in report.diagnostics.iter().filter(|d| d.severity == crate::verify::Severity::Error) {
        s.push_str(&format!("\n  {d}\n"));
        for line in d.context.lines() {
            s.push_str(&format!("    {line}\n"));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_table_renders_all_layers() {
        let g = crate::models::tinycnn(crate::graph::Shape::new(24, 32, 3), 10);
        let cfg = ArchConfig::j3dai();
        let (_, tr) = crate::sim::simulate_traced(&g, &cfg).unwrap();
        let t = render_layer_table(&tr);
        for l in &g.layers {
            assert!(t.contains(&l.name), "missing layer {} in:\n{t}", l.name);
        }
        assert!(t.contains("total"));
    }

    #[test]
    fn diagnostics_table_renders_clean_and_dirty() {
        use crate::isa::{Instr, Program};
        use crate::verify::{verify_programs, VerifyPolicy};
        let cfg = ArchConfig::j3dai();
        let clean = verify_programs(
            &[Program { instrs: vec![Instr::LayerMark { id: 0 }, Instr::Halt] }],
            &cfg,
            &VerifyPolicy::default(),
        );
        let t = render_diagnostics("mbv1", &clean);
        assert!(t.contains("0 error(s)"), "{t}");
        assert!(t.contains("clean"), "{t}");
        let dirty = verify_programs(&[Program { instrs: vec![Instr::Sync] }], &cfg, &VerifyPolicy::default());
        let t = render_diagnostics("mbv1", &dirty);
        assert!(t.contains("structure.missing-halt"), "{t}");
        assert!(t.contains("->"), "{t}"); // listing context of the error
    }

    #[test]
    fn bench_json_is_valid_and_has_overhead() {
        let e = BenchEntry {
            model: "mbv1".into(),
            latency_ms: 4.9,
            mac_eff: 0.76,
            plain_wall_ms: vec![2.0, 2.2, 2.1],
            traced_wall_ms: vec![2.4, 2.2, 2.3],
        };
        let text = bench_telemetry_json(&[e]);
        let doc = json::Json::parse(&text).unwrap();
        let arr = doc.get("benchmarks").and_then(json::Json::as_arr).unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("model").and_then(json::Json::as_str), Some("mbv1"));
        // p50 plain = 2.1, p50 traced = 2.3 -> ~9.5% overhead
        let ov = arr[0].get("trace_overhead_pct").and_then(json::Json::as_f64).unwrap();
        assert!((ov - (2.3 / 2.1 - 1.0) * 100.0).abs() < 1e-9);
    }

    #[test]
    fn layer_table_has_energy_and_intensity_columns() {
        let g = crate::models::tinycnn(crate::graph::Shape::new(24, 32, 3), 10);
        let cfg = ArchConfig::j3dai();
        let (_, tr) = crate::sim::simulate_traced(&g, &cfg).unwrap();
        let t = render_layer_table(&tr);
        assert!(t.contains("E mJ"), "{t}");
        assert!(t.contains("MACs/B"), "{t}");
        assert!(t.contains("Top stall"), "{t}");
    }

    #[test]
    fn stall_and_cluster_tables_account_for_cycles() {
        let g = crate::models::tinycnn(crate::graph::Shape::new(24, 32, 3), 10);
        let cfg = ArchConfig::j3dai();
        let em = EnergyModel::fdsoi28();
        let r = crate::sim::simulate(&g, &cfg).unwrap();
        let t = render_stall_table(&g, &r);
        assert!(t.contains("weight_refill"), "{t}");
        // every cluster's accounting line must close: busy+ctrl+stalls==cycles
        assert_eq!(t.matches("[OK]").count(), cfg.clusters, "{t}");
        assert!(!t.contains("MISMATCH"), "{t}");
        let ct = render_cluster_table(&r, &em);
        assert!(ct.contains("Top stall"), "{ct}");
        assert!(ct.contains("E mJ"), "{ct}");
        assert!(ct.contains("total"), "{ct}");
    }

    #[test]
    fn roofline_svg_draws_layers_and_ceilings() {
        let g = crate::models::tinycnn(crate::graph::Shape::new(24, 32, 3), 10);
        let cfg = ArchConfig::j3dai();
        let (_, tr) = crate::sim::simulate_traced(&g, &cfg).unwrap();
        let svg = roofline_svg(&tr, &cfg);
        assert!(svg.starts_with("<svg "), "{svg}");
        assert!(svg.ends_with("</svg>\n"));
        // one circle per layer plus the two legend dots
        assert_eq!(svg.matches("<circle").count(), tr.layers.len() + 2, "{svg}");
        assert!(svg.contains("DMPA 25.6 GB/s"), "{svg}");
        assert!(svg.contains("DMA 1.6 GB/s"), "{svg}");
        assert!(svg.contains("memory-bound"));
        assert_eq!(svg.matches("<title>").count(), tr.layers.len());
    }

    #[test]
    fn roofline_classifies_against_the_right_ceiling() {
        let cfg = ArchConfig::j3dai();
        // 128 B/cycle * 200 MHz = 25.6 GB/s; 8 B/cycle * 200 MHz = 1.6 GB/s
        assert!((dmpa_bw_gbs(&cfg) - 25.6).abs() < 1e-9);
        assert!((dma_bw_gbs(&cfg) - 1.6).abs() < 1e-9);

        let g = crate::models::tinycnn(crate::graph::Shape::new(24, 32, 3), 10);
        let (_, tr) = crate::sim::simulate_traced(&g, &cfg).unwrap();
        let pts = roofline_points(&tr, &cfg);
        assert_eq!(pts.len(), tr.layers.len());
        for p in &pts {
            assert!(p.attainable_gops <= cfg.peak_gops() + 1e-9, "{}", p.name);
            assert!(p.attainable_gops > 0.0, "{}", p.name);
            // the classification is consistent with the ceiling actually used
            assert_eq!(
                p.memory_bound,
                2.0 * p.intensity * p.bw_gbs < cfg.peak_gops(),
                "{}",
                p.name
            );
            // achieved throughput never beats the model's own ceiling by
            // more than rounding (setup cycles keep it below in practice)
            assert!(p.achieved_gops <= cfg.peak_gops() * 1.000001, "{}", p.name);
        }
        let text = render_roofline(&tr, &cfg);
        assert!(text.contains("ridge"), "{text}");
        assert!(text.contains("memory-bound"), "{text}");
    }

    #[test]
    fn ppa_json_is_valid_and_complete() {
        let cfg = ArchConfig::j3dai();
        let em = EnergyModel::fdsoi28();
        let r = crate::sim::simulate(&crate::models::paper_seg(), &cfg).unwrap();
        let text = bench_ppa_json(&cfg, &[ppa_entry(&r, &em)]);
        let doc = json::Json::parse(&text).unwrap();
        let arch = doc.get("arch").unwrap();
        assert_eq!(arch.get("macs_per_cycle").and_then(json::Json::as_f64), Some(768.0));
        let models = doc.get("models").and_then(json::Json::as_arr).unwrap();
        assert_eq!(models.len(), 1);
        let m = &models[0];
        assert!(m.get("energy_mj").and_then(json::Json::as_f64).unwrap() > 0.0);
        // seg cannot sustain 200 FPS: the field must be JSON null, not 0
        assert_eq!(m.get("power_mw_200"), Some(&json::Json::Null));
        assert!(m.get("power_mw_30").and_then(json::Json::as_f64).unwrap() > 0.0);
    }

    #[test]
    fn sony_columns_match_paper_ratios() {
        let cols = sony_columns();
        // GOPS/W/mm2: 0.98*1000/124 = 7.9 ; 1.33*1000/262 = 5.1
        assert!((cols[0].gops_w_mm2().unwrap() - 7.9).abs() < 0.05);
        assert!((cols[1].gops_w_mm2().unwrap() - 5.1).abs() < 0.05);
    }

    #[test]
    fn render_smoke() {
        let cols = sony_columns();
        let t2 = render_table2(&cols);
        assert!(t2.contains("GOPS/W/mm2"));
        let cfg = ArchConfig::j3dai();
        let f5 = render_floorplan(&area::bottom_die(&cfg));
        assert!(f5.contains("L2 SRAM"));
        let f6 = render_fig6();
        assert!(f6.contains("J3DAI"));
    }
}
