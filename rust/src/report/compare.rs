//! Bench-trajectory comparison — diffs two or more `BENCH_ppa.json`
//! snapshots and gates CI on regressions past configurable thresholds
//! (`j3dai bench-compare old.json new.json`).
//!
//! The first file is the baseline, the last is the candidate; files in
//! between only add columns to the trajectory table. Null JSON cells (the
//! paper's "-" entries, e.g. power at an unsustainable frame rate) print
//! as "-" and regress only when a previously-present metric disappears.

use crate::telemetry::json::Json;

/// One model's PPA metrics parsed from a `BENCH_ppa.json` snapshot. Every
/// metric is optional: the writer emits JSON null where the paper prints
/// "-".
#[derive(Debug, Clone, Default)]
pub struct BenchModel {
    pub model: String,
    pub latency_ms: Option<f64>,
    pub energy_mj: Option<f64>,
    pub power_mw_30: Option<f64>,
    pub power_mw_200: Option<f64>,
    pub tops_per_w: Option<f64>,
    pub mac_eff: Option<f64>,
}

/// One parsed snapshot: a display label (the file name) plus its models.
#[derive(Debug, Clone)]
pub struct BenchFile {
    pub label: String,
    pub models: Vec<BenchModel>,
}

/// Parse one `BENCH_ppa.json` document.
pub fn parse_bench_ppa(label: &str, text: &str) -> crate::Result<BenchFile> {
    let doc = Json::parse(text)?;
    let models = doc
        .get("models")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("{label}: missing \"models\" array"))?;
    let num = |m: &Json, k: &str| m.get(k).and_then(Json::as_f64);
    let parsed = models
        .iter()
        .map(|m| {
            let name = m
                .get("model")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("{label}: model entry without a name"))?;
            Ok(BenchModel {
                model: name.to_string(),
                latency_ms: num(m, "latency_ms"),
                energy_mj: num(m, "energy_mj"),
                power_mw_30: num(m, "power_mw_30"),
                power_mw_200: num(m, "power_mw_200"),
                tops_per_w: num(m, "tops_per_w"),
                mac_eff: num(m, "mac_eff"),
            })
        })
        .collect::<crate::Result<Vec<_>>>()?;
    Ok(BenchFile { label: label.to_string(), models: parsed })
}

/// Regression tolerances, percent of the baseline value.
#[derive(Debug, Clone, Copy)]
pub struct CompareThresholds {
    pub latency_pct: f64,
    pub power_pct: f64,
    pub tops_w_pct: f64,
}

impl Default for CompareThresholds {
    fn default() -> Self {
        CompareThresholds { latency_pct: 5.0, power_pct: 10.0, tops_w_pct: 10.0 }
    }
}

/// One detected regression (candidate worse than baseline past tolerance).
#[derive(Debug, Clone)]
pub struct Regression {
    pub model: String,
    pub metric: &'static str,
    pub detail: String,
}

/// Comparison output: the rendered trajectory table plus the gated
/// regressions (empty = pass).
#[derive(Debug, Clone)]
pub struct Comparison {
    pub table: String,
    pub regressions: Vec<Regression>,
}

/// The metrics a trajectory row tracks: `(name, higher_is_better, gated)`.
/// Ungated metrics (energy, MAC efficiency) are informational rows only.
const METRICS: [(&str, bool, bool); 6] = [
    ("latency_ms", false, true),
    ("energy_mj", false, false),
    ("power_mw_30", false, true),
    ("power_mw_200", false, true),
    ("tops_per_w", true, true),
    ("mac_eff", true, false),
];

fn metric(m: &BenchModel, name: &str) -> Option<f64> {
    match name {
        "latency_ms" => m.latency_ms,
        "energy_mj" => m.energy_mj,
        "power_mw_30" => m.power_mw_30,
        "power_mw_200" => m.power_mw_200,
        "tops_per_w" => m.tops_per_w,
        "mac_eff" => m.mac_eff,
        _ => None,
    }
}

fn tolerance(thr: &CompareThresholds, name: &str) -> f64 {
    match name {
        "latency_ms" => thr.latency_pct,
        "power_mw_30" | "power_mw_200" => thr.power_pct,
        "tops_per_w" => thr.tops_w_pct,
        _ => f64::INFINITY,
    }
}

fn opt_cell(v: Option<f64>) -> String {
    v.map(|x| format!("{x:.4}")).unwrap_or_else(|| "-".into())
}

fn delta_cell(v: Option<f64>) -> String {
    v.map(|x| format!("{x:+.1}")).unwrap_or_else(|| "-".into())
}

fn clip(s: &str, n: usize) -> String {
    let chars: Vec<char> = s.chars().collect();
    if chars.len() <= n {
        s.to_string()
    } else {
        chars[chars.len() - n..].iter().collect()
    }
}

/// Compare two or more snapshots: baseline = first file, candidate = last.
/// Returns the trajectory table and every gated regression; the caller
/// (CLI) exits non-zero when `regressions` is non-empty.
pub fn compare(files: &[BenchFile], thr: &CompareThresholds) -> crate::Result<Comparison> {
    anyhow::ensure!(files.len() >= 2, "bench-compare needs at least two files");
    let base = &files[0];
    let cand = files.last().unwrap();

    let mut table = String::from("Bench trajectory (baseline = first, candidate = last)\n");
    table.push_str(&format!("{:<14} {:<14}", "Model", "Metric"));
    for f in files {
        table.push_str(&format!(" {:>16}", clip(&f.label, 16)));
    }
    table.push_str(&format!(" {:>8}\n", "delta %"));

    let mut regressions = Vec::new();
    for bm in &base.models {
        let Some(cm) = cand.models.iter().find(|m| m.model == bm.model) else {
            let detail = format!("{} missing from {}", bm.model, cand.label);
            regressions.push(Regression { model: bm.model.clone(), metric: "model", detail });
            continue;
        };
        for (name, higher_better, gated) in METRICS {
            table.push_str(&format!("{:<14} {:<14}", bm.model, name));
            for f in files {
                let v =
                    f.models.iter().find(|m| m.model == bm.model).and_then(|m| metric(m, name));
                table.push_str(&format!(" {:>16}", opt_cell(v)));
            }
            let (b, c) = (metric(bm, name), metric(cm, name));
            let delta = match (b, c) {
                (Some(bv), Some(cv)) if bv != 0.0 => Some((cv / bv - 1.0) * 100.0),
                _ => None,
            };
            table.push_str(&format!(" {:>8}\n", delta_cell(delta)));
            if !gated {
                continue;
            }
            let tol = tolerance(thr, name);
            match (b, c) {
                (Some(bv), Some(cv)) => {
                    let pct = if bv != 0.0 { (cv / bv - 1.0) * 100.0 } else { 0.0 };
                    let worse = if higher_better { -pct } else { pct };
                    if worse > tol {
                        let detail =
                            format!("{name} {bv:.4} -> {cv:.4} ({pct:+.1}%, tolerance {tol}%)");
                        regressions.push(Regression {
                            model: bm.model.clone(),
                            metric: name,
                            detail,
                        });
                    }
                }
                (Some(bv), None) => {
                    let detail = format!("{name} {bv:.4} -> null (metric disappeared)");
                    regressions.push(Regression { model: bm.model.clone(), metric: name, detail });
                }
                _ => {} // baseline null: nothing to gate against
            }
        }
    }
    Ok(Comparison { table, regressions })
}

/// One model's throughput metrics parsed from a `BENCH_throughput.json`
/// snapshot (`j3dai bench-throughput`).
#[derive(Debug, Clone, Default)]
pub struct ThroughputModel {
    pub model: String,
    pub sim_wall_ms_1t: Option<f64>,
    pub sim_wall_ms_nt: Option<f64>,
    pub speedup: Option<f64>,
    pub frames_per_s: Option<f64>,
}

/// One parsed throughput snapshot: label (file name) plus its models.
#[derive(Debug, Clone)]
pub struct ThroughputFile {
    pub label: String,
    pub models: Vec<ThroughputModel>,
}

/// Parse one `BENCH_throughput.json` document. The `"bench": "throughput"`
/// tag is required — feeding a `BENCH_ppa.json` here is an error, not a
/// silently empty comparison.
pub fn parse_bench_throughput(label: &str, text: &str) -> crate::Result<ThroughputFile> {
    let doc = Json::parse(text)?;
    anyhow::ensure!(
        doc.get("bench").and_then(Json::as_str) == Some("throughput"),
        "{label}: not a bench-throughput file (missing \"bench\": \"throughput\")"
    );
    let models = doc
        .get("models")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("{label}: missing \"models\" array"))?;
    let num = |m: &Json, k: &str| m.get(k).and_then(Json::as_f64);
    let parsed = models
        .iter()
        .map(|m| {
            let name = m
                .get("model")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("{label}: model entry without a name"))?;
            Ok(ThroughputModel {
                model: name.to_string(),
                sim_wall_ms_1t: num(m, "sim_wall_ms_1t"),
                sim_wall_ms_nt: num(m, "sim_wall_ms_nt"),
                speedup: num(m, "speedup"),
                frames_per_s: num(m, "frames_per_s"),
            })
        })
        .collect::<crate::Result<Vec<_>>>()?;
    Ok(ThroughputFile { label: label.to_string(), models: parsed })
}

/// Throughput regression tolerances, percent of the baseline value. Only
/// the two scale-invariant metrics gate: speedup (sim parallel scaling)
/// and frames/s (pipeline throughput, loose — CI runners are noisy). Raw
/// wall-times never gate; they don't transfer across machines.
#[derive(Debug, Clone, Copy)]
pub struct ThroughputThresholds {
    pub speedup_pct: f64,
    pub fps_pct: f64,
}

impl Default for ThroughputThresholds {
    fn default() -> Self {
        ThroughputThresholds { speedup_pct: 25.0, fps_pct: 60.0 }
    }
}

/// The throughput metrics: `(name, higher_is_better, gated)`.
const THROUGHPUT_METRICS: [(&str, bool, bool); 4] = [
    ("sim_wall_ms_1t", false, false),
    ("sim_wall_ms_nt", false, false),
    ("speedup", true, true),
    ("frames_per_s", true, true),
];

fn throughput_metric(m: &ThroughputModel, name: &str) -> Option<f64> {
    match name {
        "sim_wall_ms_1t" => m.sim_wall_ms_1t,
        "sim_wall_ms_nt" => m.sim_wall_ms_nt,
        "speedup" => m.speedup,
        "frames_per_s" => m.frames_per_s,
        _ => None,
    }
}

fn throughput_tolerance(thr: &ThroughputThresholds, name: &str) -> f64 {
    match name {
        "speedup" => thr.speedup_pct,
        "frames_per_s" => thr.fps_pct,
        _ => f64::INFINITY,
    }
}

/// Compare throughput snapshots: baseline = first file, candidate = last.
/// Same trajectory-table + gated-regressions contract as [`compare`].
pub fn compare_throughput(
    files: &[ThroughputFile],
    thr: &ThroughputThresholds,
) -> crate::Result<Comparison> {
    anyhow::ensure!(files.len() >= 2, "bench-compare needs at least two files");
    let base = &files[0];
    let cand = files.last().unwrap();

    let mut table = String::from("Throughput trajectory (baseline = first, candidate = last)\n");
    table.push_str(&format!("{:<14} {:<14}", "Model", "Metric"));
    for f in files {
        table.push_str(&format!(" {:>16}", clip(&f.label, 16)));
    }
    table.push_str(&format!(" {:>8}\n", "delta %"));

    let mut regressions = Vec::new();
    for bm in &base.models {
        let Some(cm) = cand.models.iter().find(|m| m.model == bm.model) else {
            let detail = format!("{} missing from {}", bm.model, cand.label);
            regressions.push(Regression { model: bm.model.clone(), metric: "model", detail });
            continue;
        };
        for (name, higher_better, gated) in THROUGHPUT_METRICS {
            table.push_str(&format!("{:<14} {:<14}", bm.model, name));
            for f in files {
                let v = f
                    .models
                    .iter()
                    .find(|m| m.model == bm.model)
                    .and_then(|m| throughput_metric(m, name));
                table.push_str(&format!(" {:>16}", opt_cell(v)));
            }
            let (b, c) = (throughput_metric(bm, name), throughput_metric(cm, name));
            let delta = match (b, c) {
                (Some(bv), Some(cv)) if bv != 0.0 => Some((cv / bv - 1.0) * 100.0),
                _ => None,
            };
            table.push_str(&format!(" {:>8}\n", delta_cell(delta)));
            if !gated {
                continue;
            }
            let tol = throughput_tolerance(thr, name);
            match (b, c) {
                (Some(bv), Some(cv)) => {
                    let pct = if bv != 0.0 { (cv / bv - 1.0) * 100.0 } else { 0.0 };
                    let worse = if higher_better { -pct } else { pct };
                    if worse > tol {
                        let detail =
                            format!("{name} {bv:.4} -> {cv:.4} ({pct:+.1}%, tolerance {tol}%)");
                        regressions.push(Regression {
                            model: bm.model.clone(),
                            metric: name,
                            detail,
                        });
                    }
                }
                (Some(bv), None) => {
                    let detail = format!("{name} {bv:.4} -> null (metric disappeared)");
                    regressions.push(Regression { model: bm.model.clone(), metric: name, detail });
                }
                _ => {} // baseline null (e.g. committed wall-times): nothing to gate
            }
        }
    }
    Ok(Comparison { table, regressions })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snapshot(label: &str, latency: f64, p200: Option<f64>, topsw: f64) -> BenchFile {
        BenchFile {
            label: label.into(),
            models: vec![BenchModel {
                model: "mbv1_1_1".into(),
                latency_ms: Some(latency),
                energy_mj: Some(1.2),
                power_mw_30: Some(47.0),
                power_mw_200: p200,
                tops_per_w: Some(topsw),
                mac_eff: Some(0.76),
            }],
        }
    }

    #[test]
    fn parses_ppa_json_with_null_cells() {
        let text = r#"{"arch": {"clusters": 6},
            "models": [{"model": "fpnseg_1_2", "latency_ms": 7.43, "energy_mj": null,
                        "power_mw_30": 63.8, "power_mw_200": null, "tops_per_w": 0.82,
                        "mac_eff": 0.765, "max_fps": null}]}"#;
        let f = parse_bench_ppa("paper", text).unwrap();
        assert_eq!(f.models.len(), 1);
        let m = &f.models[0];
        assert_eq!(m.model, "fpnseg_1_2");
        assert_eq!(m.latency_ms, Some(7.43));
        assert_eq!(m.power_mw_200, None);
        assert_eq!(m.energy_mj, None);
        // malformed documents error instead of panicking
        assert!(parse_bench_ppa("bad", "{\"models\": 3}").is_err());
        assert!(parse_bench_ppa("bad", "not json").is_err());
    }

    #[test]
    fn within_tolerance_passes() {
        let base = snapshot("base.json", 5.0, Some(290.0), 0.77);
        let cand = snapshot("cand.json", 5.2, Some(300.0), 0.75);
        let cmp = compare(&[base, cand], &CompareThresholds::default()).unwrap();
        assert!(cmp.regressions.is_empty(), "{:?}", cmp.regressions);
        assert!(cmp.table.contains("latency_ms"), "{}", cmp.table);
        assert!(cmp.table.contains("base.json"), "{}", cmp.table);
    }

    #[test]
    fn latency_regression_detected() {
        let base = snapshot("base.json", 5.0, Some(290.0), 0.77);
        let cand = snapshot("cand.json", 5.6, Some(290.0), 0.77);
        let cmp = compare(&[base, cand], &CompareThresholds::default()).unwrap();
        assert_eq!(cmp.regressions.len(), 1, "{:?}", cmp.regressions);
        assert_eq!(cmp.regressions[0].metric, "latency_ms");
        assert!(cmp.regressions[0].detail.contains("tolerance"), "{:?}", cmp.regressions);
    }

    #[test]
    fn efficiency_drop_and_improvements_gate_correctly() {
        // TOPS/W is higher-is-better: a 20% drop past the 10% tolerance gates
        let base = snapshot("base.json", 5.0, Some(290.0), 0.80);
        let cand = snapshot("cand.json", 5.0, Some(290.0), 0.64);
        let cmp = compare(&[base, cand], &CompareThresholds::default()).unwrap();
        assert_eq!(cmp.regressions.len(), 1, "{:?}", cmp.regressions);
        assert_eq!(cmp.regressions[0].metric, "tops_per_w");
        // improvements on every axis never regress
        let base = snapshot("base.json", 5.0, Some(290.0), 0.77);
        let cand = snapshot("cand.json", 4.0, Some(200.0), 0.95);
        let cmp = compare(&[base, cand], &CompareThresholds::default()).unwrap();
        assert!(cmp.regressions.is_empty(), "{:?}", cmp.regressions);
    }

    #[test]
    fn disappearing_metric_regresses_but_null_baseline_does_not() {
        let base = snapshot("base.json", 5.0, Some(290.0), 0.77);
        let cand = snapshot("cand.json", 5.0, None, 0.77);
        let cmp = compare(&[base, cand], &CompareThresholds::default()).unwrap();
        assert_eq!(cmp.regressions.len(), 1, "{:?}", cmp.regressions);
        assert_eq!(cmp.regressions[0].metric, "power_mw_200");
        // None -> Some never gates, and null cells render as "-"
        let base = snapshot("base.json", 5.0, None, 0.77);
        let cand = snapshot("cand.json", 5.0, Some(290.0), 0.77);
        let cmp = compare(&[base, cand], &CompareThresholds::default()).unwrap();
        assert!(cmp.regressions.is_empty(), "{:?}", cmp.regressions);
        assert!(cmp.table.contains(" -"), "{}", cmp.table);
    }

    #[test]
    fn missing_model_is_a_regression() {
        let base = snapshot("base.json", 5.0, Some(290.0), 0.77);
        let mut cand = snapshot("cand.json", 5.0, Some(290.0), 0.77);
        cand.models[0].model = "other".into();
        let cmp = compare(&[base, cand], &CompareThresholds::default()).unwrap();
        assert_eq!(cmp.regressions.len(), 1, "{:?}", cmp.regressions);
        assert_eq!(cmp.regressions[0].metric, "model");
    }

    #[test]
    fn three_files_gate_only_first_vs_last() {
        let base = snapshot("a.json", 5.0, Some(290.0), 0.77);
        let mid = snapshot("b.json", 9.0, Some(400.0), 0.30); // bad middle run
        let cand = snapshot("c.json", 5.1, Some(292.0), 0.77);
        let cmp = compare(&[base, mid, cand], &CompareThresholds::default()).unwrap();
        assert!(cmp.regressions.is_empty(), "{:?}", cmp.regressions);
        for label in ["a.json", "b.json", "c.json"] {
            assert!(cmp.table.contains(label), "{}", cmp.table);
        }
    }

    #[test]
    fn round_trips_generated_bench_ppa() {
        let cfg = crate::config::ArchConfig::j3dai();
        let em = crate::power::EnergyModel::fdsoi28();
        let r = crate::sim::simulate(&crate::models::paper_mbv1(), &cfg).unwrap();
        let text = super::super::bench_ppa_json(&cfg, &[super::super::ppa_entry(&r, &em)]);
        let f = parse_bench_ppa("gen", &text).unwrap();
        assert_eq!(f.models[0].model, "mbv1_1_1");
        // identical snapshots never regress, even at zero tolerance
        let thr = CompareThresholds { latency_pct: 0.0, power_pct: 0.0, tops_w_pct: 0.0 };
        let cmp = compare(&[f.clone(), f], &thr).unwrap();
        assert!(cmp.regressions.is_empty(), "{:?}", cmp.regressions);
    }

    fn tp_snapshot(label: &str, speedup: f64, fps: f64) -> ThroughputFile {
        ThroughputFile {
            label: label.into(),
            models: vec![ThroughputModel {
                model: "fpnseg_1_2".into(),
                sim_wall_ms_1t: Some(120.0),
                sim_wall_ms_nt: Some(120.0 / speedup),
                speedup: Some(speedup),
                frames_per_s: Some(fps),
            }],
        }
    }

    #[test]
    fn parses_throughput_json_and_rejects_ppa() {
        let text = super::super::bench_throughput_json(
            4,
            4,
            3,
            &[super::super::ThroughputEntry {
                model: "fpnseg_1_2".into(),
                twin: "fpnseg_w25_48x64".into(),
                sim_wall_ms_1t: 120.0,
                sim_wall_ms_nt: 40.0,
                speedup: 3.0,
                frames_per_s: 95.5,
                frames: 24,
            }],
        );
        let f = parse_bench_throughput("gen", &text).unwrap();
        assert_eq!(f.models.len(), 1);
        let m = &f.models[0];
        assert_eq!(m.model, "fpnseg_1_2");
        assert_eq!(m.speedup, Some(3.0));
        assert_eq!(m.frames_per_s, Some(95.5));
        // a bench-ppa document must be rejected, not parsed as empty
        assert!(parse_bench_throughput("ppa", "{\"models\": []}").is_err());
    }

    #[test]
    fn throughput_speedup_regression_gates_but_wall_time_does_not() {
        // speedup collapse past tolerance gates
        let base = tp_snapshot("base.json", 3.0, 90.0);
        let cand = tp_snapshot("cand.json", 1.5, 90.0);
        let cmp = compare_throughput(&[base, cand], &ThroughputThresholds::default()).unwrap();
        assert_eq!(cmp.regressions.len(), 1, "{:?}", cmp.regressions);
        assert_eq!(cmp.regressions[0].metric, "speedup");
        // a slower machine (same speedup, 10x wall time) never gates
        let base = tp_snapshot("base.json", 3.0, 90.0);
        let mut cand = tp_snapshot("cand.json", 3.0, 90.0);
        cand.models[0].sim_wall_ms_1t = Some(1200.0);
        cand.models[0].sim_wall_ms_nt = Some(400.0);
        let cmp = compare_throughput(&[base, cand], &ThroughputThresholds::default()).unwrap();
        assert!(cmp.regressions.is_empty(), "{:?}", cmp.regressions);
        assert!(cmp.table.contains("sim_wall_ms_1t"), "{}", cmp.table);
    }

    #[test]
    fn throughput_null_wall_time_baseline_passes() {
        // the committed baseline ships null wall-times (machine-dependent):
        // candidates with real timings must compare clean
        let mut base = tp_snapshot("base.json", 1.0, 10.0);
        base.models[0].sim_wall_ms_1t = None;
        base.models[0].sim_wall_ms_nt = None;
        let cand = tp_snapshot("cand.json", 3.0, 90.0);
        let cmp = compare_throughput(&[base, cand], &ThroughputThresholds::default()).unwrap();
        assert!(cmp.regressions.is_empty(), "{:?}", cmp.regressions);
        let base = tp_snapshot("base.json", 3.0, 200.0);
        let cand = tp_snapshot("cand.json", 3.0, 60.0); // fps -70% past the 60% tol
        let cmp = compare_throughput(&[base, cand], &ThroughputThresholds::default()).unwrap();
        assert_eq!(cmp.regressions.len(), 1, "{:?}", cmp.regressions);
        assert_eq!(cmp.regressions[0].metric, "frames_per_s");
    }
}
