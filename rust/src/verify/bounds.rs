//! Bounds/capacity pass — every `Dmpa*`/`Dma*` transfer window checked
//! against the compiler-visible L2 arena and the cluster's NCB-local SRAM
//! capacity, with TSV-crossing transfers optionally enumerated.
//!
//! Address semantics mirror the compiler's memory model, not a literal
//! banked address map: the L2 side of a transfer indexes the unified
//! placement arena ([`ArchConfig::l2_arena_bytes`]) and the local side
//! indexes the cluster's flat NCB-SRAM window
//! ([`ArchConfig::cluster_local_bytes`]). A local window whose *base* is
//! in range but whose extent runs past the SRAM top is not an error: the
//! multi-banked buffers stream tiles larger than residency (the §III-B1
//! flattened organization), so it demotes to a warning — only a base
//! address outside the SRAM entirely is a hard error.

use super::{Ctx, Pass, Severity};
use crate::isa::{Instr, Space};

pub(crate) fn run(ctx: &mut Ctx<'_>) {
    let arena = ctx.cfg.l2_arena_bytes() as u64;
    let local_cap = ctx.cfg.cluster_local_bytes() as u64;
    for pc in 0..ctx.prog.instrs.len() {
        let (far_space, far_addr, local_addr, bytes) = match ctx.prog.instrs[pc] {
            Instr::DmpaLoad { src, src_addr, dst_addr, bytes }
            | Instr::DmaLoad { src, src_addr, dst_addr, bytes } => (src, src_addr, dst_addr, bytes),
            Instr::DmpaStore { dst, dst_addr, src_addr, bytes }
            | Instr::DmaStore { dst, dst_addr, src_addr, bytes } => (dst, dst_addr, src_addr, bytes),
            _ => continue,
        };
        if bytes == 0 {
            ctx.diag(
                Severity::Warning,
                Pass::Bounds,
                "bounds.empty-transfer",
                pc,
                "transfer moves 0 bytes (pays setup cycles for nothing)".into(),
            );
        }
        // local side of the transfer
        check_local(ctx, pc, local_addr, bytes, local_cap);
        // far side: normally an L2 partition; a Local far side makes the
        // transfer local-to-local, so both windows face the SRAM bound.
        if far_space == Space::Local {
            check_local(ctx, pc, far_addr, bytes, local_cap);
        } else if far_addr as u64 >= arena {
            ctx.diag(
                Severity::Error,
                Pass::Bounds,
                "bounds.l2-oob",
                pc,
                format!("L2 address {far_addr:#x} is outside the {arena}-byte placement arena"),
            );
        } else if far_addr as u64 + bytes as u64 > arena {
            ctx.diag(
                Severity::Error,
                Pass::Bounds,
                "bounds.l2-overflow",
                pc,
                format!(
                    "L2 window {far_addr:#x}+{bytes} runs {} byte(s) past the {arena}-byte placement arena",
                    far_addr as u64 + bytes as u64 - arena
                ),
            );
        }
        if ctx.policy.flag_tsv && ctx.prog.instrs[pc].crosses_tsv() {
            ctx.diag(
                Severity::Note,
                Pass::Bounds,
                "bounds.tsv-crossing",
                pc,
                format!("{bytes}-byte transfer crosses the middle-die TSVs"),
            );
        }
    }
}

fn check_local(ctx: &mut Ctx<'_>, pc: usize, addr: u32, bytes: u32, cap: u64) {
    if addr as u64 >= cap {
        ctx.diag(
            Severity::Error,
            Pass::Bounds,
            "bounds.local-oob",
            pc,
            format!("local address {addr:#x} is outside the {cap}-byte cluster SRAM"),
        );
    } else if addr as u64 + bytes as u64 > cap {
        ctx.diag(
            Severity::Warning,
            Pass::Bounds,
            "bounds.local-spill",
            pc,
            format!(
                "local window {addr:#x}+{bytes} exceeds the {cap}-byte cluster SRAM \
                 (assumed streamed through the multi-banked buffers)"
            ),
        );
    }
}

#[cfg(test)]
mod tests {
    use crate::config::ArchConfig;
    use crate::isa::{Instr, Program, Space};
    use crate::verify::{verify_programs, Severity, VerifyPolicy};

    fn wrap(body: Vec<Instr>) -> Vec<Instr> {
        let mut v = vec![Instr::LayerMark { id: 0 }];
        v.extend(body);
        v.push(Instr::Sync);
        v.push(Instr::Halt);
        v
    }

    fn codes(instrs: Vec<Instr>) -> Vec<&'static str> {
        let r = verify_programs(&[Program { instrs }], &ArchConfig::j3dai(), &VerifyPolicy::default());
        r.diagnostics.iter().map(|d| d.code).collect()
    }

    #[test]
    fn local_oob_is_error_spill_is_warning() {
        let cap = ArchConfig::j3dai().cluster_local_bytes() as u32;
        let oob = codes(wrap(vec![Instr::DmpaLoad {
            src: Space::L2Bottom,
            src_addr: 0,
            dst_addr: cap,
            bytes: 16,
        }]));
        assert!(oob.contains(&"bounds.local-oob"), "{oob:?}");
        let spill = wrap(vec![Instr::DmpaLoad {
            src: Space::L2Bottom,
            src_addr: 0,
            dst_addr: cap - 1,
            bytes: 16,
        }]);
        let r = verify_programs(&[Program { instrs: spill }], &ArchConfig::j3dai(), &VerifyPolicy::default());
        assert!(r.is_clean(), "{}", r.render_text());
        assert_eq!(r.count(Severity::Warning), 1);
        assert_eq!(r.diagnostics[0].code, "bounds.local-spill");
    }

    #[test]
    fn l2_windows_checked_against_arena() {
        let arena = ArchConfig::j3dai().l2_arena_bytes() as u32;
        let oob = codes(wrap(vec![Instr::DmaStore {
            dst: Space::L2Bottom,
            dst_addr: arena,
            src_addr: 0,
            bytes: 8,
        }]));
        assert!(oob.contains(&"bounds.l2-oob"), "{oob:?}");
        let over = codes(wrap(vec![Instr::DmaStore {
            dst: Space::L2Middle,
            dst_addr: arena - 4,
            src_addr: 0,
            bytes: 8,
        }]));
        assert!(over.contains(&"bounds.l2-overflow"), "{over:?}");
    }

    #[test]
    fn empty_transfer_warns() {
        let c = codes(wrap(vec![Instr::DmaLoad { src: Space::L2Bottom, src_addr: 0, dst_addr: 0, bytes: 0 }]));
        assert!(c.contains(&"bounds.empty-transfer"), "{c:?}");
    }
}
