//! Diagnostic exporters — SARIF 2.1.0 (the static-analysis interchange
//! format CI systems ingest) and a plain JSON summary.
//!
//! One SARIF `run` per verified model; each diagnostic becomes a `result`
//! whose `ruleId` is the stable verifier rule code and whose location
//! points at a virtual listing artifact `<model>/cluster<N>.j3dai-asm`
//! with `startLine = pc + 1` (the listing is line-per-instruction, so a
//! SARIF viewer lands on the offending macro-op).

use std::collections::BTreeSet;

use super::{Diagnostic, Severity, VerifyReport};
use crate::telemetry::json::escape;

impl Severity {
    /// SARIF `level` for this severity.
    pub fn sarif_level(self) -> &'static str {
        match self {
            Severity::Note => "note",
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

fn sarif_result(model: &str, d: &Diagnostic) -> String {
    let uri = format!("{}/cluster{}.j3dai-asm", escape(model), d.cluster);
    format!(
        concat!(
            "{{\"ruleId\":\"{}\",\"level\":\"{}\",\"message\":{{\"text\":\"{}\"}},",
            "\"locations\":[{{\"physicalLocation\":{{\"artifactLocation\":{{\"uri\":\"{}\"}},",
            "\"region\":{{\"startLine\":{}}}}}}}],",
            "\"properties\":{{\"pass\":\"{}\",\"cluster\":{},\"pc\":{}}}}}"
        ),
        d.code,
        d.severity.sarif_level(),
        escape(&d.message),
        uri,
        d.pc + 1,
        d.pass.label(),
        d.cluster,
        d.pc,
    )
}

/// Render one SARIF 2.1.0 document with one run per `(model, report)`.
pub fn to_sarif(reports: &[(String, VerifyReport)]) -> String {
    let mut runs = Vec::new();
    for (model, report) in reports {
        let rules: BTreeSet<&'static str> = report.diagnostics.iter().map(|d| d.code).collect();
        let rules_json: Vec<String> = rules.iter().map(|r| format!("{{\"id\":\"{r}\"}}")).collect();
        let results: Vec<String> =
            report.diagnostics.iter().map(|d| sarif_result(model, d)).collect();
        runs.push(format!(
            concat!(
                "{{\"tool\":{{\"driver\":{{\"name\":\"j3dai-verify\",",
                "\"informationUri\":\"docs/VERIFIER.md\",\"rules\":[{}]}}}},",
                "\"properties\":{{\"model\":\"{}\"}},",
                "\"results\":[{}]}}"
            ),
            rules_json.join(","),
            escape(model),
            results.join(","),
        ));
    }
    format!(
        concat!(
            "{{\"$schema\":\"https://json.schemastore.org/sarif-2.1.0.json\",",
            "\"version\":\"2.1.0\",\"runs\":[{}]}}"
        ),
        runs.join(",")
    )
}

/// Plain JSON summary (the `lint --json` payload).
pub fn to_json(reports: &[(String, VerifyReport)]) -> String {
    let mut models = Vec::new();
    for (model, report) in reports {
        let diags: Vec<String> = report
            .diagnostics
            .iter()
            .map(|d| {
                format!(
                    concat!(
                        "{{\"severity\":\"{}\",\"pass\":\"{}\",\"rule\":\"{}\",",
                        "\"cluster\":{},\"pc\":{},\"message\":\"{}\"}}"
                    ),
                    d.severity.label(),
                    d.pass.label(),
                    d.code,
                    d.cluster,
                    d.pc,
                    escape(&d.message),
                )
            })
            .collect();
        models.push(format!(
            "{{\"model\":\"{}\",\"errors\":{},\"warnings\":{},\"notes\":{},\"diagnostics\":[{}]}}",
            escape(model),
            report.error_count(),
            report.warning_count(),
            report.note_count(),
            diags.join(","),
        ));
    }
    format!("{{\"models\":[{}]}}", models.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchConfig;
    use crate::isa::{Instr, Program};
    use crate::telemetry::json::Json;
    use crate::verify::{verify_programs, VerifyPolicy};

    fn report_with_findings() -> VerifyReport {
        // missing halt + unattributed work -> at least one error, one warning
        verify_programs(
            &[Program { instrs: vec![Instr::AddTile { n: 4 }] }],
            &ArchConfig::j3dai(),
            &VerifyPolicy::default(),
        )
    }

    #[test]
    fn sarif_is_valid_json_with_schema_and_rules() {
        let reports = vec![("mbv1".to_string(), report_with_findings())];
        let doc = Json::parse(&to_sarif(&reports)).unwrap();
        assert_eq!(doc.get("version").unwrap().as_str(), Some("2.1.0"));
        let runs = doc.get("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 1);
        let results = runs[0].get("results").unwrap().as_arr().unwrap();
        assert!(!results.is_empty());
        assert!(results[0].get("ruleId").unwrap().as_str().unwrap().contains('.'));
    }

    #[test]
    fn json_summary_counts_match_report() {
        let rep = report_with_findings();
        let (errs, warns) = (rep.error_count(), rep.warning_count());
        let doc = Json::parse(&to_json(&[("seg".to_string(), rep)])).unwrap();
        let models = doc.get("models").unwrap().as_arr().unwrap();
        assert_eq!(models[0].get("errors").unwrap().as_f64().unwrap() as usize, errs);
        assert_eq!(models[0].get("warnings").unwrap().as_f64().unwrap() as usize, warns);
    }

    #[test]
    fn clean_report_renders_empty_results() {
        let doc = to_sarif(&[("mbv2".to_string(), VerifyReport::default())]);
        let parsed = Json::parse(&doc).unwrap();
        let runs = parsed.get("runs").unwrap().as_arr().unwrap();
        let results = runs[0].get("results").unwrap().as_arr().unwrap();
        assert!(results.is_empty());
    }
}
