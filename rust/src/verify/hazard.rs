//! Hazard pass — abstract interpretation of the two-engine overlap.
//!
//! The simulator (and the real cluster controller) runs transfers and
//! tile computations on decoupled engines that only meet at `Sync`
//! (§III-C2's "masking parameter loading" double buffering). Between two
//! barriers, a load that rewrites a local buffer a not-yet-retired
//! compute still reads is a WAR race; rewriting a buffer that was loaded
//! in the *same* epoch with no compute in between is an outright clobber
//! of data nothing consumed yet. Stores issued while computes from the
//! same epoch are still in flight read an accumulator that may not be
//! drained.
//!
//! The abstraction: each resident load (window strictly inside the
//! cluster SRAM — streamed/spilled windows are the bounds pass's
//! business) becomes a pending write with an *age* = number of compute
//! ops issued since it. Age 0 overlap → clobber error; age 1 → the
//! single-buffering warning (the consumer compute may still be running
//! when the rewrite lands); age ≥ 2 → proper double buffering, the slot
//! has provably retired. `Sync` retires everything.

use super::{Ctx, Pass, Severity};
use crate::isa::{Engine, Instr};

struct PendingWrite {
    pc: usize,
    lo: u64,
    hi: u64,
    /// Compute ops issued since this load, in the same sync epoch.
    age: u32,
}

pub(crate) fn run(ctx: &mut Ctx<'_>) {
    let cap = ctx.cfg.cluster_local_bytes() as u64;
    let mut pending: Vec<PendingWrite> = Vec::new();
    let mut computes_since_sync = 0u32;
    for pc in 0..ctx.prog.instrs.len() {
        match ctx.prog.instrs[pc] {
            Instr::DmpaLoad { dst_addr, bytes, .. } | Instr::DmaLoad { dst_addr, bytes, .. } => {
                let (lo, hi) = (dst_addr as u64, dst_addr as u64 + bytes as u64);
                // only windows strictly inside the SRAM are resident
                // buffers; anything touching the top streams through the
                // banked FIFOs and has no stable address to race on.
                if bytes == 0 || hi >= cap {
                    continue;
                }
                for w in pending.iter().filter(|w| w.lo < hi && lo < w.hi) {
                    match w.age {
                        0 => ctx.diag(
                            Severity::Error,
                            Pass::Hazard,
                            "hazard.clobber",
                            pc,
                            format!(
                                "load rewrites local [{:#x}, {:#x}) loaded at pc {} with no compute in between",
                                lo, hi, w.pc
                            ),
                        ),
                        1 => ctx.diag(
                            Severity::Warning,
                            Pass::Hazard,
                            "hazard.single-buffer",
                            pc,
                            format!(
                                "load rewrites local [{:#x}, {:#x}) while the compute consuming the pc-{} load \
                                 may still be in flight (single buffering; insert a sync or a second slot)",
                                lo, hi, w.pc
                            ),
                        ),
                        _ => {}
                    }
                }
                pending.retain(|w| !(w.lo < hi && lo < w.hi));
                pending.push(PendingWrite { pc, lo, hi, age: 0 });
            }
            Instr::DmpaStore { .. } | Instr::DmaStore { .. } => {
                if computes_since_sync > 0 {
                    ctx.diag(
                        Severity::Error,
                        Pass::Hazard,
                        "hazard.store-race",
                        pc,
                        format!(
                            "store issued with {computes_since_sync} compute op(s) in flight since the last \
                             sync — the accumulator drain may not have completed"
                        ),
                    );
                }
            }
            Instr::Sync => {
                pending.clear();
                computes_since_sync = 0;
            }
            ref i if i.engine() == Engine::Compute => {
                for w in &mut pending {
                    w.age += 1;
                }
                computes_since_sync += 1;
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::config::ArchConfig;
    use crate::isa::{Instr, Program, Space};
    use crate::verify::{verify_programs, VerifyPolicy, VerifyReport};

    fn load(dst_addr: u32, bytes: u32) -> Instr {
        Instr::DmpaLoad { src: Space::L2Bottom, src_addr: 0, dst_addr, bytes }
    }

    fn conv() -> Instr {
        Instr::ConvTile { m: 8, k: 8, n: 8, first: true, last: true }
    }

    fn verify(body: Vec<Instr>) -> VerifyReport {
        let mut instrs = vec![Instr::LayerMark { id: 0 }];
        instrs.extend(body);
        instrs.push(Instr::Sync);
        instrs.push(Instr::Halt);
        verify_programs(&[Program { instrs }], &ArchConfig::j3dai(), &VerifyPolicy::default())
    }

    fn codes(r: &VerifyReport) -> Vec<&'static str> {
        r.diagnostics.iter().map(|d| d.code).collect()
    }

    #[test]
    fn back_to_back_load_same_buffer_is_clobber() {
        let r = verify(vec![load(0, 1024), load(0, 1024), Instr::Sync, conv()]);
        assert!(codes(&r).contains(&"hazard.clobber"), "{}", r.render_text());
    }

    #[test]
    fn single_buffer_rewrite_warns() {
        let r = verify(vec![load(0, 1024), conv(), load(0, 1024), Instr::Sync, conv()]);
        assert!(r.is_clean(), "{}", r.render_text());
        assert!(codes(&r).contains(&"hazard.single-buffer"), "{}", r.render_text());
    }

    #[test]
    fn double_buffering_is_clean() {
        // ping-pong: two slots, each rewritten only after >= 2 computes
        let r = verify(vec![
            load(0, 1024),
            load(0x1000, 1024),
            conv(),
            conv(),
            load(0, 1024),
            conv(),
            conv(),
            Instr::Sync,
        ]);
        assert!(r.is_clean(), "{}", r.render_text());
        assert_eq!(r.warning_count(), 0, "{}", r.render_text());
    }

    #[test]
    fn sync_retires_pending_writes() {
        let r = verify(vec![load(0, 1024), Instr::Sync, load(0, 1024), Instr::Sync, conv()]);
        assert!(r.is_clean(), "{}", r.render_text());
        assert_eq!(r.warning_count(), 0, "{}", r.render_text());
    }

    #[test]
    fn store_with_inflight_compute_is_error() {
        let r = verify(vec![
            load(0, 1024),
            Instr::Sync,
            conv(),
            Instr::DmpaStore { dst: Space::L2Bottom, dst_addr: 0, src_addr: 0, bytes: 64 },
            Instr::Sync,
        ]);
        assert!(codes(&r).contains(&"hazard.store-race"), "{}", r.render_text());
    }

    #[test]
    fn streamed_oversize_window_is_untracked() {
        let cap = ArchConfig::j3dai().cluster_local_bytes() as u32;
        // both windows run past the SRAM top -> streamed, no race tracked
        let r = verify(vec![load(0, cap + 64), load(0, cap + 64), Instr::Sync, conv()]);
        assert!(r.is_clean(), "{}", r.render_text());
        assert!(!codes(&r).contains(&"hazard.clobber"), "{}", r.render_text());
    }
}
