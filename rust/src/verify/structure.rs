//! Structure pass — whole-program shape rules.
//!
//! Every cluster program must end in exactly one `Halt` (the host-interrupt
//! handshake the runtime blocks on); anything after the first `Halt` never
//! executes; and work issued before the first `LayerMark` cannot be
//! attributed to a graph layer, which silently corrupts the telemetry
//! spans and the per-layer energy/latency tables.

use super::{Ctx, Pass, Severity};
use crate::isa::{Engine, Instr};

pub(crate) fn run(ctx: &mut Ctx<'_>) {
    let n = ctx.prog.instrs.len();
    let halt = ctx.prog.instrs.iter().position(|i| *i == Instr::Halt);
    match halt {
        None => ctx.diag(
            Severity::Error,
            Pass::Structure,
            "structure.missing-halt",
            n.saturating_sub(1),
            "program never halts — the host interrupt is never raised".into(),
        ),
        Some(h) if h + 1 < n => ctx.diag(
            Severity::Error,
            Pass::Structure,
            "structure.unreachable",
            h + 1,
            format!("{} instruction(s) after halt are unreachable", n - h - 1),
        ),
        Some(_) => {}
    }
    for pc in 0..n {
        match ctx.prog.instrs[pc] {
            Instr::LayerMark { .. } => break,
            ref i if i.engine() != Engine::Control => {
                ctx.diag(
                    Severity::Warning,
                    Pass::Structure,
                    "structure.unattributed",
                    pc,
                    format!(
                        "{} issued before any layer.mark — telemetry cannot attribute it to a layer",
                        i.mnemonic()
                    ),
                );
                break;
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::config::ArchConfig;
    use crate::isa::{Instr, Program, Space};
    use crate::verify::{verify_programs, VerifyPolicy, VerifyReport};

    fn verify(instrs: Vec<Instr>) -> VerifyReport {
        verify_programs(&[Program { instrs }], &ArchConfig::j3dai(), &VerifyPolicy::default())
    }

    fn codes(r: &VerifyReport) -> Vec<&'static str> {
        r.diagnostics.iter().map(|d| d.code).collect()
    }

    #[test]
    fn missing_halt_flagged() {
        let r = verify(vec![Instr::LayerMark { id: 0 }, Instr::Sync]);
        assert!(codes(&r).contains(&"structure.missing-halt"), "{}", r.render_text());
    }

    #[test]
    fn code_after_halt_is_unreachable() {
        let r = verify(vec![Instr::LayerMark { id: 0 }, Instr::Halt, Instr::Sync]);
        assert!(codes(&r).contains(&"structure.unreachable"), "{}", r.render_text());
    }

    #[test]
    fn work_before_layer_mark_warns() {
        let r = verify(vec![
            Instr::DmpaLoad { src: Space::L2Bottom, src_addr: 0, dst_addr: 0, bytes: 64 },
            Instr::LayerMark { id: 0 },
            Instr::Sync,
            Instr::Halt,
        ]);
        assert!(r.is_clean(), "{}", r.render_text());
        assert!(codes(&r).contains(&"structure.unattributed"), "{}", r.render_text());
    }

    #[test]
    fn empty_program_reports_missing_halt_once() {
        let r = verify(vec![]);
        assert_eq!(codes(&r), vec!["structure.missing-halt"]);
    }
}
