//! Compute-protocol pass — the rules the NCB datapath imposes on the
//! compute stream.
//!
//! A GEMM larger than one tile runs as a ConvTile *chain*: `first` clears
//! the int32 accumulators, intermediate tiles accumulate, `last` drains
//! them through the fused requant path back to int8. A chain that never
//! sees `last` leaves int32 partials nothing will requant; a tile with
//! `first` while a chain is open silently discards the open partials; a
//! chain whose m/n change mid-flight accumulates mismatched shapes. The
//! accumulated `k` across the chain also bounds accumulator magnitude:
//! int8 x int8 products are at most 127*128 = 16256, so k_total tiles of
//! worst-case products overflow i32 once k_total > i32::MAX / 16384.
//!
//! The pass also checks AIU loop-register discipline (registers
//! configured in order, non-zero trip counts) and routing: with the AIU
//! disabled, the spatially-routed tiles (ConvTile/DwTile/AddTile) need an
//! explicit `RouteCfg` in scope, while with the AIU enabled a `RouteCfg`
//! is dead weight the AIU ignores (§III-B2). ActTile/PoolTile run on the
//! fixed-function NLU/pooling path and never need routing.

use super::{Ctx, Pass, Severity};
use crate::isa::{Instr, NUM_AIU_LOOP_REGS};

/// Conservative chain-k bound: int8 x int8 products reach 127*128 < 2^14,
/// so i32 accumulation is safe while k_total <= i32::MAX / 2^14 = 131071.
pub const MAX_CHAIN_K: u64 = (i32::MAX as u64) >> 14;

struct Chain {
    start_pc: usize,
    m: u32,
    n: u32,
    k_total: u64,
}

pub(crate) fn run(ctx: &mut Ctx<'_>) {
    let mut chain: Option<Chain> = None;
    let mut loops_set: u32 = 0; // bitmask of AIU regs configured in scope
    let mut routed = false;
    let n = ctx.prog.instrs.len();
    for pc in 0..n {
        match ctx.prog.instrs[pc] {
            Instr::ConvTile { m, k, n, first, last } => {
                match (&mut chain, first) {
                    (None, true) => {
                        chain = Some(Chain { start_pc: pc, m, n, k_total: k as u64 });
                    }
                    (None, false) => {
                        ctx.diag(
                            Severity::Error,
                            Pass::Protocol,
                            "protocol.chain-missing-first",
                            pc,
                            "ConvTile accumulates without `first` — reads uninitialized int32 accumulators"
                                .into(),
                        );
                        chain = Some(Chain { start_pc: pc, m, n, k_total: k as u64 });
                    }
                    (Some(c), true) => {
                        ctx.diag(
                            Severity::Error,
                            Pass::Protocol,
                            "protocol.chain-dangling",
                            pc,
                            format!(
                                "`first` discards the open accumulator chain started at pc {} \
                                 (its partials were never requantized with `last`)",
                                c.start_pc
                            ),
                        );
                        chain = Some(Chain { start_pc: pc, m, n, k_total: k as u64 });
                    }
                    (Some(c), false) => {
                        if c.m != m || c.n != n {
                            ctx.diag(
                                Severity::Error,
                                Pass::Protocol,
                                "protocol.chain-shape",
                                pc,
                                format!(
                                    "chain tile is {m}x{n} but the chain started at pc {} is {}x{}",
                                    c.start_pc, c.m, c.n
                                ),
                            );
                        }
                        c.k_total += k as u64;
                    }
                }
                if last {
                    if let Some(c) = chain.take() {
                        if c.k_total > MAX_CHAIN_K {
                            ctx.diag(
                                Severity::Error,
                                Pass::Protocol,
                                "protocol.acc-overflow",
                                pc,
                                format!(
                                    "accumulator chain sums k_total={} int8 products; beyond {MAX_CHAIN_K} \
                                     the int32 accumulator can overflow before requant",
                                    c.k_total
                                ),
                            );
                        }
                    }
                }
                check_routing(ctx, pc, &mut routed);
            }
            Instr::DwTile { .. } | Instr::AddTile { .. } => {
                break_chain(ctx, &mut chain, pc);
                check_routing(ctx, pc, &mut routed);
            }
            Instr::ActTile { .. } | Instr::PoolTile { .. } => {
                // fixed-function NLU / pooling path — no routing needed
                break_chain(ctx, &mut chain, pc);
            }
            Instr::AiuLoop { reg, count, .. } => {
                if !ctx.cfg.aiu_enabled {
                    ctx.diag(
                        Severity::Warning,
                        Pass::Protocol,
                        "protocol.aiu-disabled",
                        pc,
                        "aiu.loop configured but the AIU is disabled in this ArchConfig (ignored)".into(),
                    );
                }
                if reg >= NUM_AIU_LOOP_REGS {
                    ctx.diag(
                        Severity::Error,
                        Pass::Protocol,
                        "protocol.bad-loop-reg",
                        pc,
                        format!("AIU loop register r{reg} out of range 0..{NUM_AIU_LOOP_REGS}"),
                    );
                } else {
                    if reg > 0 && loops_set & (1 << (reg - 1)) == 0 {
                        ctx.diag(
                            Severity::Warning,
                            Pass::Protocol,
                            "protocol.loop-order",
                            pc,
                            format!(
                                "loop register r{reg} configured before r{} — the AIU nests loops \
                                 outermost-first",
                                reg - 1
                            ),
                        );
                    }
                    loops_set |= 1 << reg;
                }
                if count == 0 {
                    ctx.diag(
                        Severity::Warning,
                        Pass::Protocol,
                        "protocol.empty-loop",
                        pc,
                        format!("loop register r{reg} has a zero trip count"),
                    );
                }
            }
            Instr::RouteCfg { .. } => {
                if ctx.cfg.aiu_enabled {
                    ctx.diag(
                        Severity::Warning,
                        Pass::Protocol,
                        "protocol.dead-routecfg",
                        pc,
                        "route.cfg is dead with the AIU enabled — the AIU drives routing itself".into(),
                    );
                }
                routed = true;
            }
            Instr::LayerMark { .. } => {
                break_chain(ctx, &mut chain, pc);
                loops_set = 0;
                routed = false;
            }
            Instr::Sync | Instr::Halt => break_chain(ctx, &mut chain, pc),
            _ => {}
        }
    }
    if let Some(c) = chain {
        ctx.diag(
            Severity::Error,
            Pass::Protocol,
            "protocol.chain-broken",
            n.saturating_sub(1),
            format!(
                "program ends with the accumulator chain started at pc {} still open (no `last` tile)",
                c.start_pc
            ),
        );
    }
}

/// Anything that is not a non-`last` chain tile closes an open chain: the
/// partials it held are lost without a requant drain.
fn break_chain(ctx: &mut Ctx<'_>, chain: &mut Option<Chain>, pc: usize) {
    if let Some(c) = chain.take() {
        ctx.diag(
            Severity::Error,
            Pass::Protocol,
            "protocol.chain-broken",
            pc,
            format!(
                "{} interrupts the accumulator chain started at pc {} before its `last` tile",
                ctx.prog.instrs[pc].mnemonic(),
                c.start_pc
            ),
        );
    }
}

/// With the AIU off, a spatially-routed tile needs a RouteCfg in scope.
fn check_routing(ctx: &mut Ctx<'_>, pc: usize, routed: &mut bool) {
    if !ctx.cfg.aiu_enabled && !*routed {
        ctx.diag(
            Severity::Error,
            Pass::Protocol,
            "protocol.unrouted-tile",
            pc,
            format!(
                "{} issued with the AIU disabled and no route.cfg in scope — the NCB routing \
                 fabric is unconfigured",
                ctx.prog.instrs[pc].mnemonic()
            ),
        );
        // suppress a cascade: one diagnostic per unrouted scope
        *routed = true;
    }
}

#[cfg(test)]
mod tests {
    use super::MAX_CHAIN_K;
    use crate::config::ArchConfig;
    use crate::isa::{Instr, Program};
    use crate::verify::{verify_programs, VerifyPolicy, VerifyReport};

    fn conv(first: bool, last: bool) -> Instr {
        Instr::ConvTile { m: 8, k: 64, n: 8, first, last }
    }

    fn verify_with(cfg: &ArchConfig, body: Vec<Instr>) -> VerifyReport {
        let mut instrs = vec![Instr::LayerMark { id: 0 }];
        instrs.extend(body);
        instrs.push(Instr::Sync);
        instrs.push(Instr::Halt);
        verify_programs(&[Program { instrs }], cfg, &VerifyPolicy::default())
    }

    fn verify(body: Vec<Instr>) -> VerifyReport {
        verify_with(&ArchConfig::j3dai(), body)
    }

    fn codes(r: &VerifyReport) -> Vec<&'static str> {
        r.diagnostics.iter().map(|d| d.code).collect()
    }

    #[test]
    fn well_formed_chain_is_clean() {
        let r = verify(vec![conv(true, false), conv(false, false), conv(false, true)]);
        assert!(r.is_clean(), "{}", r.render_text());
        assert_eq!(r.diagnostics.len(), 0, "{}", r.render_text());
    }

    #[test]
    fn missing_first_and_dangling_chain_flagged() {
        let r = verify(vec![conv(false, true)]);
        assert!(codes(&r).contains(&"protocol.chain-missing-first"), "{}", r.render_text());
        let r = verify(vec![conv(true, false), conv(true, true)]);
        assert!(codes(&r).contains(&"protocol.chain-dangling"), "{}", r.render_text());
    }

    #[test]
    fn sync_breaks_an_open_chain() {
        let r = verify(vec![conv(true, false), Instr::Sync, conv(false, true)]);
        assert!(codes(&r).contains(&"protocol.chain-broken"), "{}", r.render_text());
    }

    #[test]
    fn chain_shape_mismatch_flagged() {
        let r = verify(vec![
            conv(true, false),
            Instr::ConvTile { m: 16, k: 64, n: 8, first: false, last: true },
        ]);
        assert!(codes(&r).contains(&"protocol.chain-shape"), "{}", r.render_text());
    }

    #[test]
    fn accumulator_overflow_bound() {
        let k = (MAX_CHAIN_K + 1) as u32;
        let r = verify(vec![Instr::ConvTile { m: 8, k, n: 8, first: true, last: true }]);
        assert!(codes(&r).contains(&"protocol.acc-overflow"), "{}", r.render_text());
        let r = verify(vec![Instr::ConvTile { m: 8, k: k - 1, n: 8, first: true, last: true }]);
        assert!(r.is_clean(), "{}", r.render_text());
    }

    #[test]
    fn loop_register_discipline() {
        let r = verify(vec![Instr::AiuLoop { reg: 1, count: 4, stride: 1 }]);
        assert!(codes(&r).contains(&"protocol.loop-order"), "{}", r.render_text());
        let r = verify(vec![Instr::AiuLoop { reg: 0, count: 0, stride: 1 }]);
        assert!(codes(&r).contains(&"protocol.empty-loop"), "{}", r.render_text());
        let r = verify(vec![
            Instr::AiuLoop { reg: 0, count: 4, stride: 1 },
            Instr::AiuLoop { reg: 1, count: 4, stride: 1 },
        ]);
        assert_eq!(r.diagnostics.len(), 0, "{}", r.render_text());
    }

    #[test]
    fn routing_rules_follow_aiu_setting() {
        let mut off = ArchConfig::j3dai();
        off.aiu_enabled = false;
        let r = verify_with(&off, vec![conv(true, true)]);
        assert!(codes(&r).contains(&"protocol.unrouted-tile"), "{}", r.render_text());
        let r = verify_with(&off, vec![Instr::RouteCfg { pattern: 0 }, conv(true, true)]);
        assert!(r.is_clean(), "{}", r.render_text());
        assert_eq!(r.warning_count(), 0, "{}", r.render_text());
        // with the AIU on, RouteCfg is dead weight
        let r = verify(vec![Instr::RouteCfg { pattern: 0 }, conv(true, true)]);
        assert!(codes(&r).contains(&"protocol.dead-routecfg"), "{}", r.render_text());
        // ActTile never needs routing
        let r = verify_with(&off, vec![Instr::ActTile { n: 64, nlu: true }]);
        assert!(r.is_clean(), "{}", r.render_text());
    }
}
