//! Static program verifier — multi-pass analysis over compiled
//! [`Program`]s, parameterized by the [`ArchConfig`] the programs were
//! compiled for.
//!
//! The cluster controller blindly sequences whatever macro-op stream the
//! compiler hands it: an out-of-bounds transfer, a ConvTile chain that
//! drops its requant slice, or an Xfer/Compute overlap that races on a
//! local buffer silently produces wrong pixels or wrong PPA numbers.
//! This module is the correctness backstop: four passes walk each cluster
//! program and report [`Diagnostic`]s —
//!
//! - [`bounds`]    — transfer windows vs the L2 arena and NCB-local SRAM
//!   capacity, TSV-crossing transfers flagged per [`VerifyPolicy`];
//! - [`hazard`]    — abstract interpretation of the two-engine overlap
//!   across `Sync` barriers: WAR/WAW races on resident local-SRAM buffers
//!   (double-buffering violations) and stores racing in-flight computes;
//! - [`protocol`]  — the ConvTile `first`/`last` accumulator-chain state
//!   machine, int32 accumulator overflow bounds, AIU loop-register
//!   discipline and dead `RouteCfg`;
//! - [`structure`] — missing/duplicated `Halt`, unreachable code, and
//!   instructions outside any `LayerMark` scope (breaks telemetry
//!   attribution).
//!
//! `compiler::codegen::emit` runs the verifier as a debug assertion, so
//! every sim/test path in a debug build self-checks its programs for free;
//! the `lint` CLI subcommand runs it on demand with human-table, JSON and
//! SARIF output (see docs/VERIFIER.md).

pub mod bounds;
pub mod hazard;
pub mod protocol;
pub mod sarif;
pub mod structure;

use std::fmt;

use crate::config::ArchConfig;
use crate::isa::Program;

/// Diagnostic severity. Only `Error` fails the `lint` gate by default;
/// warnings gate under `--deny-warnings`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Note,
    Warning,
    Error,
}

impl Severity {
    pub fn label(self) -> &'static str {
        match self {
            Severity::Note => "note",
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// Which analysis pass produced a diagnostic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pass {
    Bounds,
    Hazard,
    Protocol,
    Structure,
}

impl Pass {
    pub fn label(self) -> &'static str {
        match self {
            Pass::Bounds => "bounds",
            Pass::Hazard => "hazard",
            Pass::Protocol => "protocol",
            Pass::Structure => "structure",
        }
    }
}

/// One finding: severity, producing pass, a stable rule code (the SARIF
/// ruleId), the cluster/pc it anchors to, a message, and a rendered
/// listing window around the offending instruction.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub severity: Severity,
    pub pass: Pass,
    /// Stable rule id, e.g. `bounds.local-oob`.
    pub code: &'static str,
    /// Index of the cluster program the diagnostic is in.
    pub cluster: usize,
    /// Program counter (instruction index) the diagnostic anchors to.
    pub pc: usize,
    pub message: String,
    /// Listing context around `pc` (the offending line marked with `->`).
    pub context: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}[{}] cluster {} pc {}: {}",
            self.severity.label(),
            self.code,
            self.cluster,
            self.pc,
            self.message
        )
    }
}

/// Policy knobs for a verification run.
#[derive(Debug, Clone)]
pub struct VerifyPolicy {
    /// Emit a note for every TSV-crossing transfer. Off by default: the
    /// paper's placement legitimately spills parameters to the middle die,
    /// but an energy audit wants the crossings enumerated.
    pub flag_tsv: bool,
    /// Listing lines of context on each side of a diagnosed instruction.
    pub context_lines: usize,
}

impl Default for VerifyPolicy {
    fn default() -> Self {
        VerifyPolicy { flag_tsv: false, context_lines: 2 }
    }
}

/// All diagnostics from verifying a set of cluster programs.
#[derive(Debug, Clone, Default)]
pub struct VerifyReport {
    pub diagnostics: Vec<Diagnostic>,
}

impl VerifyReport {
    pub fn count(&self, s: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == s).count()
    }

    pub fn error_count(&self) -> usize {
        self.count(Severity::Error)
    }

    pub fn warning_count(&self) -> usize {
        self.count(Severity::Warning)
    }

    pub fn note_count(&self) -> usize {
        self.count(Severity::Note)
    }

    /// True when no error-severity diagnostics were produced.
    pub fn is_clean(&self) -> bool {
        self.error_count() == 0
    }

    /// Plain-text rendering: one block per diagnostic with its listing
    /// context (the `lint --context` / debug-assert failure format).
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        for d in &self.diagnostics {
            s.push_str(&format!("{d}\n"));
            for line in d.context.lines() {
                s.push_str(&format!("    {line}\n"));
            }
        }
        s
    }
}

/// Shared pass state: the program under analysis plus the diagnostic sink.
pub(crate) struct Ctx<'a> {
    pub prog: &'a Program,
    pub cluster: usize,
    pub cfg: &'a ArchConfig,
    pub policy: &'a VerifyPolicy,
    pub out: Vec<Diagnostic>,
}

impl Ctx<'_> {
    pub(crate) fn diag(&mut self, severity: Severity, pass: Pass, code: &'static str, pc: usize, message: String) {
        let context = listing_window(self.prog, pc, self.policy.context_lines);
        self.out.push(Diagnostic { severity, pass, code, cluster: self.cluster, pc, message, context });
    }
}

/// Render the listing lines around `pc`, marking the diagnosed one.
fn listing_window(p: &Program, pc: usize, n: usize) -> String {
    let lo = pc.saturating_sub(n);
    let hi = (pc + n + 1).min(p.instrs.len());
    let mut s = String::new();
    for i in lo..hi {
        let mark = if i == pc { "->" } else { "  " };
        s.push_str(&format!("{mark} {i:5}: {}\n", p.instrs[i]));
    }
    s
}

/// Run all four passes over one cluster program.
pub fn verify_program(prog: &Program, cluster: usize, cfg: &ArchConfig, policy: &VerifyPolicy) -> Vec<Diagnostic> {
    let mut ctx = Ctx { prog, cluster, cfg, policy, out: Vec::new() };
    bounds::run(&mut ctx);
    hazard::run(&mut ctx);
    protocol::run(&mut ctx);
    structure::run(&mut ctx);
    let mut out = ctx.out;
    out.sort_by_key(|d| (d.pc, std::cmp::Reverse(d.severity)));
    out
}

/// Run the verifier over every cluster program of a compiled model.
pub fn verify_programs(progs: &[Program], cfg: &ArchConfig, policy: &VerifyPolicy) -> VerifyReport {
    let mut report = VerifyReport::default();
    for (ci, p) in progs.iter().enumerate() {
        report.diagnostics.extend(verify_program(p, ci, cfg, policy));
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Instr, Space};

    fn cfg() -> ArchConfig {
        ArchConfig::j3dai()
    }

    fn verify(instrs: Vec<Instr>) -> VerifyReport {
        verify_programs(&[Program { instrs }], &cfg(), &VerifyPolicy::default())
    }

    #[test]
    fn minimal_clean_program() {
        let r = verify(vec![
            Instr::LayerMark { id: 0 },
            Instr::DmpaLoad { src: Space::L2Bottom, src_addr: 0, dst_addr: 0, bytes: 1024 },
            Instr::Sync,
            Instr::ConvTile { m: 8, k: 8, n: 8, first: true, last: true },
            Instr::Sync,
            Instr::DmpaStore { dst: Space::L2Bottom, dst_addr: 0x1000, src_addr: 0, bytes: 64 },
            Instr::Sync,
            Instr::Halt,
        ]);
        assert!(r.is_clean(), "{}", r.render_text());
        assert_eq!(r.diagnostics.len(), 0, "{}", r.render_text());
    }

    #[test]
    fn diagnostics_render_with_context() {
        let r = verify(vec![Instr::LayerMark { id: 0 }, Instr::Sync]);
        // missing halt
        assert_eq!(r.error_count(), 1);
        let d = &r.diagnostics[0];
        assert_eq!(d.pass, Pass::Structure);
        assert!(d.to_string().contains("structure.missing-halt"), "{d}");
        assert!(d.context.contains("->"), "{}", d.context);
        assert!(r.render_text().contains("sync"));
    }

    #[test]
    fn tsv_policy_flags_crossings() {
        let instrs = vec![
            Instr::LayerMark { id: 0 },
            Instr::DmaLoad { src: Space::L2Middle, src_addr: 0, dst_addr: 0, bytes: 64 },
            Instr::Sync,
            Instr::Halt,
        ];
        let p = Program { instrs };
        let quiet = verify_programs(&[p.clone()], &cfg(), &VerifyPolicy::default());
        assert_eq!(quiet.note_count(), 0);
        let flagged =
            verify_programs(&[p], &cfg(), &VerifyPolicy { flag_tsv: true, ..VerifyPolicy::default() });
        assert_eq!(flagged.note_count(), 1);
        assert!(flagged.is_clean());
    }
}
