//! j3dai CLI — the leader entrypoint.
//!
//! ```text
//! j3dai serve  [--model NAME] [--fps N] [--frames N] [--workers M] [--threads N]
//!              [--trace-out F] [--metrics-addr HOST:PORT]  run the frame loop (+ live /metrics)
//! j3dai sim    [--model mbv1|mbv2|seg|all] [--threads N] [--trace-out F] [--profile-out F]
//!                                                      cycle-simulate Table I workloads
//!                                                      (+ per-cluster/per-layer stall attribution)
//! j3dai trace  [--model NAME] [--threads N] [--out trace.json] [--profile-out F]
//!                                                      traced sim -> Perfetto trace + layer table
//! j3dai sample [--model NAME] [--interval N] [--out F] cycle-binned time series -> JSON
//! j3dai roofline [--model NAME] [--svg-out F]          per-layer roofline (GOPS vs MACs/byte)
//! j3dai metrics [--model NAME] [--frames N] [--workers M] [--exemplars]
//!                                                      functional loop -> Prometheus text
//! j3dai bench-telemetry [--out BENCH_telemetry.json]   tracing-overhead benchmark file
//! j3dai bench-ppa [--out BENCH_ppa.json]               PPA regression file (energy/latency/TOPS/W)
//! j3dai bench-throughput [--threads N] [--workers M] [--iters K] [--frames N]
//!              [--out BENCH_throughput.json] [--min-speedup X]
//!                                                      parallel-sim + frame-pipeline throughput
//! j3dai bench-compare OLD.json NEW.json [--latency-tol PCT] [--power-tol PCT] [--topsw-tol PCT]
//!              [--speedup-tol PCT] [--fps-tol PCT]     PPA or throughput trajectory diff,
//!                                                      exit 1 on regression
//! j3dai table1 | table2 | fig5 | fig6                  print a paper table/figure
//! j3dai compile [--model ...]                          show mapping/schedule report
//! j3dai lint   [--model mbv1|mbv2|seg|all] [--json] [--sarif-out F] [--flag-tsv]
//!              [--deny-warnings] [--context N]         static program verifier, exit 1 on errors
//! j3dai list                                           list loaded artifacts
//! ```
//!
//! (Hand-rolled argument parsing: the offline registry has no clap.)

use anyhow::Context as _;
use j3dai::config::ArchConfig;
use j3dai::coordinator::{self, Coordinator, CoordinatorConfig};
use j3dai::power::{area, EnergyModel};
use j3dai::telemetry::{MetricsServer, Telemetry};
use j3dai::{compiler, models, report, runtime, sim};

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

/// Positional (non-flag) arguments after the subcommand. `value_flags`
/// lists the flags that consume the following token, so flag values are
/// never mistaken for positionals.
fn positionals(args: &[String], value_flags: &[&str]) -> Vec<String> {
    let mut out = Vec::new();
    let mut i = 1; // args[0] is the subcommand
    while i < args.len() {
        let a = &args[i];
        if value_flags.contains(&a.as_str()) {
            i += 2;
            continue;
        }
        if a.starts_with("--") {
            i += 1;
            continue;
        }
        out.push(a.clone());
        i += 1;
    }
    out
}

/// Canonical model key: long-form names alias the paper keys.
fn model_key(name: &str) -> &str {
    match name {
        "mobilenet_v1" | "mobilenetv1" => "mbv1",
        "mobilenet_v2" | "mobilenetv2" => "mbv2",
        "fpnseg" | "segmentation" => "seg",
        other => other,
    }
}

fn paper_graph(key: &str) -> Option<j3dai::graph::Graph> {
    match model_key(key) {
        "mbv1" => Some(models::paper_mbv1()),
        "mbv2" => Some(models::paper_mbv2()),
        "seg" => Some(models::paper_seg()),
        other => models::artifact_graph(other),
    }
}

/// Artifact twin used by `bench-throughput` for the end-to-end frame
/// pipeline: the paper workloads have no recorded golden artifacts, so the
/// pipeline runs their reduced-resolution registry twins instead.
fn throughput_twin(key: &str) -> &'static str {
    match model_key(key) {
        "mbv1" => "mbv1_w25_48x64",
        "mbv2" => "mbv2_w25_48x64",
        _ => "fpnseg_w25_48x64",
    }
}

/// Resolve `--model` or fail with the full list of accepted names — the
/// CLI's unknown-model path must say what *would* have worked.
fn require_graph(key: &str) -> j3dai::Result<j3dai::graph::Graph> {
    paper_graph(key).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown model {key:?}; accepted: mbv1 | mbv2 | seg (paper workloads) or an \
             artifact key: {}",
            models::ARTIFACT_NAMES.join(" | ")
        )
    })
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> j3dai::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let cfg = ArchConfig::j3dai();
    let em = EnergyModel::fdsoi28();

    match cmd {
        "serve" => {
            let fps: f64 = flag(&args, "--fps").and_then(|v| v.parse().ok()).unwrap_or(30.0);
            let frames: u64 = flag(&args, "--frames").and_then(|v| v.parse().ok()).unwrap_or(30);
            let workers: usize =
                flag(&args, "--workers").and_then(|v| v.parse().ok()).unwrap_or(1);
            let threads: usize = flag(&args, "--threads")
                .and_then(|v| v.parse().ok())
                .unwrap_or_else(sim::default_threads);
            let model = flag(&args, "--model").unwrap_or_else(|| "tinycnn_24x32".into());
            let coord = Coordinator::new(
                &runtime::default_artifact_dir(),
                CoordinatorConfig {
                    target_fps: fps,
                    frames,
                    workers,
                    sim_threads: threads,
                    arch: cfg,
                },
            )?;
            // the exporter shares the coordinator's registry/trace, so
            // /metrics and /trace.json are live while frames flow
            let mut exporter = match flag(&args, "--metrics-addr") {
                Some(addr) => {
                    let srv = MetricsServer::spawn(&addr, coord.telemetry_handle())?;
                    println!(
                        "metrics endpoint: http://{0}/metrics  trace: http://{0}/trace.json",
                        srv.addr()
                    );
                    Some(srv)
                }
                None => None,
            };
            let stats = coord.run_model(&model)?;
            println!(
                "{}: {} frames in {:.2}s — achieved {:.1} FPS (target {:.0})",
                stats.model, stats.frames, stats.wall_s, stats.achieved_fps, fps
            );
            println!(
                "PJRT service: mean {:.0} us, p99 {:.0} us | modeled accel: {:.2} ms/inf, {:.1} mW @ {:.0} FPS",
                stats.mean_service_us, stats.p99_service_us, stats.modeled_latency_ms, stats.modeled_power_mw_at_fps, fps
            );
            if let Some(path) = flag(&args, "--trace-out") {
                std::fs::write(&path, coord.telemetry().export_chrome_json())
                    .with_context(|| format!("cannot write trace to {path}"))?;
                println!("frame-loop trace written to {path} (open in ui.perfetto.dev)");
            }
            if let Some(srv) = exporter.as_mut() {
                if let Some(secs) = flag(&args, "--hold-secs").and_then(|v| v.parse::<f64>().ok())
                {
                    println!("holding the metrics endpoint open for {secs}s (ctrl-c to stop)");
                    std::thread::sleep(std::time::Duration::from_secs_f64(secs));
                }
                srv.shutdown();
            }
        }
        "sim" => {
            let which = flag(&args, "--model").unwrap_or_else(|| "all".into());
            let keys: Vec<&str> = if which == "all" {
                vec!["mbv1", "mbv2", "seg"]
            } else {
                vec![model_key(&which)]
            };
            let trace_out = flag(&args, "--trace-out");
            let profile_out = flag(&args, "--profile-out");
            let threads: usize = flag(&args, "--threads")
                .and_then(|v| v.parse().ok())
                .unwrap_or_else(sim::default_threads);
            let mut merged = j3dai::telemetry::TraceBuilder::new();
            let mut folded = j3dai::telemetry::FoldedProfile::new();
            for (mi, &key) in keys.iter().enumerate() {
                let g = require_graph(key)?;
                let r = if trace_out.is_some() || profile_out.is_some() {
                    let (r, mut tr) = sim::simulate_traced_threads(&g, &cfg, threads)?;
                    if keys.len() > 1 {
                        // namespace per-model stacks in a multi-model profile
                        folded.merge_prefixed(key, &tr.folded);
                    } else {
                        for (stack, w) in tr.folded.iter() {
                            folded.add(stack.to_string(), w);
                        }
                    }
                    // one process row per model so timelines don't interleave
                    tr.trace.shift_pid(mi as u32 * 10);
                    merged.merge(tr.trace);
                    r
                } else {
                    sim::simulate_threads(&g, &cfg, threads)?
                };
                println!(
                    "{:<14} {:>6.0} MMACs  {:>8} cycles  {:.2} ms  eff {:.1}%  P@30 {}",
                    r.model,
                    r.total_macs as f64 / 1e6,
                    r.cycles,
                    r.latency_ms,
                    r.mac_efficiency * 100.0,
                    r.power_mw(&em, 30.0).map(|p| format!("{p:.1} mW")).unwrap_or("-".into())
                );
                if flag(&args, "--activity").is_some() || args.iter().any(|a| a == "--activity") {
                    let a = &r.activity;
                    println!(
                        "    macs={} sram={} dmpa={} dma={} tsv={} alu={} busy={} E={:.3} mJ",
                        a.macs, a.local_sram_bytes, a.dmpa_bytes, a.dma_bytes, a.tsv_bytes, a.alu_ops,
                        a.busy_cluster_cycles, em.inference_mj(a)
                    );
                }
                print!("{}", report::render_cluster_table(&r, &em));
                print!("{}", report::render_stall_table(&g, &r));
            }
            if let Some(path) = trace_out {
                std::fs::write(&path, merged.to_chrome_json())
                    .with_context(|| format!("cannot write trace to {path}"))?;
                println!("sim trace written to {path} (open in ui.perfetto.dev)");
            }
            if let Some(path) = profile_out {
                std::fs::write(&path, folded.render())
                    .with_context(|| format!("cannot write profile to {path}"))?;
                println!("folded stacks written to {path} (inferno-flamegraph < {path} > f.svg)");
            }
        }
        "trace" => {
            let key = flag(&args, "--model").unwrap_or_else(|| "mbv1".into());
            let out = flag(&args, "--out").unwrap_or_else(|| "trace.json".into());
            let threads: usize = flag(&args, "--threads")
                .and_then(|v| v.parse().ok())
                .unwrap_or_else(sim::default_threads);
            let g = require_graph(&key)?;
            let tel = Telemetry::new(true);
            let c = compiler::compile_traced(&g, &cfg, Some(&tel))?;
            let (r, mut tr) = sim::simulate_compiled_traced_threads(&g, &cfg, &c, threads);
            tr.trace.merge(tel.take_trace()); // compiler-pass wall spans
            std::fs::write(&out, tr.trace.to_chrome_json())
                .with_context(|| format!("cannot write trace to {out}"))?;
            print!("{}", report::render_layer_table(&tr));
            println!(
                "\n{}: {:.2} ms/inference, MAC eff {:.1}% — {} spans written to {out}",
                r.model,
                r.latency_ms,
                r.mac_efficiency * 100.0,
                tr.trace.len()
            );
            println!("open in ui.perfetto.dev (\"Open trace file\") or chrome://tracing");
            if let Some(path) = flag(&args, "--profile-out") {
                std::fs::write(&path, tr.folded.render())
                    .with_context(|| format!("cannot write profile to {path}"))?;
                println!("folded stacks written to {path} (inferno-flamegraph < {path} > f.svg)");
            }
        }
        "sample" => {
            if has_flag(&args, "--help") {
                println!(
                    "j3dai sample [--model NAME] [--interval CYCLES] [--capacity N] [--out F]"
                );
                println!();
                println!("Cycle-simulate one model with the ring-buffer time-series sampler");
                println!("attached: every --interval cycles (default 4096) it snapshots");
                println!("per-cluster utilization and per-component power into a ring of");
                println!("--capacity samples (default 1024, oldest dropped) and writes the");
                println!("series as JSON (default timeseries.json — same shape as the live");
                println!("endpoint's /timeseries.json).");
                return Ok(());
            }
            let key = flag(&args, "--model").unwrap_or_else(|| "mbv1".into());
            let interval: u64 =
                flag(&args, "--interval").and_then(|v| v.parse().ok()).unwrap_or(4096);
            let capacity: usize =
                flag(&args, "--capacity").and_then(|v| v.parse().ok()).unwrap_or(1024);
            let out = flag(&args, "--out").unwrap_or_else(|| "timeseries.json".into());
            let g = require_graph(&key)?;
            let (r, sampler) = sim::sample_timeseries(&g, &cfg, interval, capacity)?;
            std::fs::write(&out, sampler.to_json())
                .with_context(|| format!("cannot write {out}"))?;
            println!(
                "{}: {} cycles sampled every {interval} -> {} samples ({} dropped) in {out}",
                r.model,
                r.cycles,
                sampler.len(),
                sampler.dropped()
            );
        }
        "metrics" => {
            let key = flag(&args, "--model").unwrap_or_else(|| "tinycnn_24x32".into());
            let frames: u64 = flag(&args, "--frames").and_then(|v| v.parse().ok()).unwrap_or(30);
            let fps: f64 = flag(&args, "--fps").and_then(|v| v.parse().ok()).unwrap_or(1000.0);
            let workers: usize =
                flag(&args, "--workers").and_then(|v| v.parse().ok()).unwrap_or(1);
            let g = require_graph(&key)?;
            let tel = Telemetry::new(false); // metrics only; no span buffer
            let ccfg = CoordinatorConfig {
                target_fps: fps,
                frames,
                workers,
                arch: cfg,
                ..Default::default()
            };
            let stats = coordinator::run_functional_loop(&g, &ccfg, &tel)?;
            if has_flag(&args, "--exemplars") {
                print!("{}", tel.registry.render_with_exemplars(true));
            } else {
                print!("{}", tel.render_metrics());
            }
            eprintln!(
                "# {} frames, mean {:.0} us, p99 {:.0} us",
                stats.frames, stats.mean_service_us, stats.p99_service_us
            );
        }
        "bench-telemetry" => {
            let out = flag(&args, "--out").unwrap_or_else(|| "BENCH_telemetry.json".into());
            let iters: usize = flag(&args, "--iters").and_then(|v| v.parse().ok()).unwrap_or(3);
            let mut entries = Vec::new();
            for key in ["mbv1", "mbv2", "seg"] {
                let g = paper_graph(key).unwrap();
                let c = compiler::compile(&g, &cfg)?;
                let r = sim::simulate(&g, &cfg)?;
                let wall_ms = |f: &dyn Fn()| {
                    let t0 = std::time::Instant::now();
                    f();
                    t0.elapsed().as_secs_f64() * 1e3
                };
                let plain: Vec<f64> = (0..iters)
                    .map(|_| wall_ms(&|| drop(sim::simulate(&g, &cfg))))
                    .collect();
                let traced: Vec<f64> = (0..iters)
                    .map(|_| wall_ms(&|| drop(sim::simulate_compiled_traced(&g, &cfg, &c))))
                    .collect();
                entries.push(report::BenchEntry {
                    model: g.name.clone(),
                    latency_ms: r.latency_ms,
                    mac_eff: r.mac_efficiency,
                    plain_wall_ms: plain,
                    traced_wall_ms: traced,
                });
                println!("benched {key}: {:.2} ms modeled latency", r.latency_ms);
            }
            std::fs::write(&out, report::bench_telemetry_json(&entries))
                .with_context(|| format!("cannot write {out}"))?;
            println!("wrote {out}");
        }
        "roofline" => {
            if has_flag(&args, "--help") {
                println!(
                    "j3dai roofline [--model mbv1|mbv2|seg|<artifact>] [--svg-out F]  (default: mbv1)"
                );
                println!();
                println!("Per-layer roofline analysis of a traced simulation: arithmetic");
                println!("intensity (MACs per off-cluster byte) against achieved GOPS, with");
                println!("the attainable ceiling set by the peak MAC rate or the DMPA/DMA");
                println!("bandwidth slope — memory-bound layers are flagged MEMORY.");
                println!("--svg-out writes the same plot as a standalone log-log SVG.");
                return Ok(());
            }
            let key = flag(&args, "--model").unwrap_or_else(|| "mbv1".into());
            let g = require_graph(&key)?;
            let (_, tr) = sim::simulate_traced(&g, &cfg)?;
            print!("{}", report::render_roofline(&tr, &cfg));
            if let Some(path) = flag(&args, "--svg-out") {
                std::fs::write(&path, report::roofline_svg(&tr, &cfg))
                    .with_context(|| format!("cannot write {path}"))?;
                println!("roofline plot written to {path}");
            }
        }
        "bench-ppa" => {
            if has_flag(&args, "--help") {
                println!("j3dai bench-ppa [--out BENCH_ppa.json]");
                println!();
                println!("Simulate the three Table I workloads (mbv1, mbv2, seg) and write");
                println!("the machine-readable PPA file: per-model energy (mJ), latency,");
                println!("power @30/@200 FPS, TOPS/W and MAC efficiency, plus the arch");
                println!("header (peak GOPS, die area). tests/ppa_regression.rs gates this");
                println!("file against the paper's Table I within tolerance.");
                return Ok(());
            }
            let out = flag(&args, "--out").unwrap_or_else(|| "BENCH_ppa.json".into());
            let mut entries = Vec::new();
            for key in ["mbv1", "mbv2", "seg"] {
                let g = require_graph(key)?;
                let r = sim::simulate(&g, &cfg)?;
                println!(
                    "{:<14} {:.2} ms  {:.3} mJ/inf  P@30 {}  eff {:.1}%",
                    r.model,
                    r.latency_ms,
                    em.inference_mj(&r.activity),
                    r.power_mw(&em, 30.0).map(|p| format!("{p:.1} mW")).unwrap_or("-".into()),
                    r.mac_efficiency * 100.0
                );
                entries.push(report::ppa_entry(&r, &em));
            }
            std::fs::write(&out, report::bench_ppa_json(&cfg, &entries))
                .with_context(|| format!("cannot write {out}"))?;
            println!("wrote {out}");
        }
        "bench-throughput" => {
            if has_flag(&args, "--help") {
                println!(
                    "j3dai bench-throughput [--threads N] [--workers M] [--iters K] \
                     [--frames N] [--out BENCH_throughput.json] [--min-speedup X]"
                );
                println!();
                println!("Benchmark the host-side parallelism: per Table I workload, time the");
                println!("cycle simulation at 1 thread and at --threads (min over --iters");
                println!("runs), and run the multi-worker functional frame pipeline on the");
                println!("model's artifact twin to measure end-to-end frames/s. Writes a");
                println!("machine-readable JSON file for bench-compare; --min-speedup fails");
                println!("the run unless the seg workload's sim speedup reaches the floor.");
                return Ok(());
            }
            let out = flag(&args, "--out").unwrap_or_else(|| "BENCH_throughput.json".into());
            let threads: usize = flag(&args, "--threads")
                .and_then(|v| v.parse().ok())
                .unwrap_or_else(sim::default_threads);
            let iters: usize = flag(&args, "--iters").and_then(|v| v.parse().ok()).unwrap_or(3);
            let frames: u64 = flag(&args, "--frames").and_then(|v| v.parse().ok()).unwrap_or(24);
            let workers: usize =
                flag(&args, "--workers").and_then(|v| v.parse().ok()).unwrap_or(threads);
            let min_speedup: Option<f64> =
                flag(&args, "--min-speedup").and_then(|v| v.parse().ok());
            let mut entries = Vec::new();
            for key in ["mbv1", "mbv2", "seg"] {
                let g = require_graph(key)?;
                let c = compiler::compile(&g, &cfg)?;
                let wall_ms = |f: &dyn Fn()| {
                    let t0 = std::time::Instant::now();
                    f();
                    t0.elapsed().as_secs_f64() * 1e3
                };
                let min_of = |n: usize| {
                    (0..iters)
                        .map(|_| wall_ms(&|| drop(sim::simulate_compiled_threads(&g, &cfg, &c, n))))
                        .fold(f64::MAX, f64::min)
                };
                let serial = min_of(1);
                let parallel = min_of(threads);
                let speedup = serial / parallel.max(1e-9);
                let twin = throughput_twin(key);
                let tg = require_graph(twin)?;
                let ccfg = CoordinatorConfig {
                    target_fps: 1e9, // unpaced: measure pipeline throughput
                    frames,
                    workers,
                    sim_threads: threads,
                    arch: cfg.clone(),
                };
                let stats = coordinator::run_functional_loop(&tg, &ccfg, &Telemetry::disabled())?;
                println!(
                    "{:<14} sim 1t {serial:>8.1} ms  {threads}t {parallel:>8.1} ms  \
                     speedup {speedup:.2}x | pipeline {twin}: {:.1} frames/s ({workers} workers)",
                    g.name, stats.achieved_fps
                );
                entries.push(report::ThroughputEntry {
                    model: g.name.clone(),
                    twin: twin.to_string(),
                    sim_wall_ms_1t: serial,
                    sim_wall_ms_nt: parallel,
                    speedup,
                    frames_per_s: stats.achieved_fps,
                    frames,
                });
            }
            std::fs::write(&out, report::bench_throughput_json(threads, workers, iters, &entries))
                .with_context(|| format!("cannot write {out}"))?;
            println!("wrote {out}");
            if let Some(floor) = min_speedup {
                for e in entries.iter().filter(|e| e.model.starts_with("fpnseg")) {
                    anyhow::ensure!(
                        e.speedup >= floor,
                        "{}: sim speedup {:.2}x at {threads} threads is below the \
                         --min-speedup floor {floor:.2}x",
                        e.model,
                        e.speedup
                    );
                }
            }
        }
        "bench-compare" => {
            let tols = [
                "--latency-tol",
                "--power-tol",
                "--topsw-tol",
                "--speedup-tol",
                "--fps-tol",
            ];
            let files = positionals(&args, &tols);
            if has_flag(&args, "--help") || files.len() < 2 {
                println!(
                    "j3dai bench-compare OLD.json NEW.json [MORE.json ...] \
                     [--latency-tol PCT] [--power-tol PCT] [--topsw-tol PCT] \
                     [--speedup-tol PCT] [--fps-tol PCT]"
                );
                println!();
                println!("Diff two or more bench-ppa output files (oldest first) and print");
                println!("the per-model PPA trajectory: latency, power @30 FPS and TOPS/W");
                println!("across runs, with the first-vs-last delta. Exits non-zero if any");
                println!("metric regressed past its tolerance (defaults: latency 5%, power");
                println!("10%, TOPS/W 10%) — wire it into CI against a committed baseline.");
                println!();
                println!("bench-throughput files are detected automatically (\"bench\":");
                println!("\"throughput\") and gated on sim speedup and pipeline frames/s");
                println!("instead (defaults: speedup 25%, fps 60%; raw wall-times are");
                println!("reported but never gated — they don't transfer across machines).");
                if files.len() < 2 && !has_flag(&args, "--help") {
                    anyhow::bail!("bench-compare needs at least two bench files");
                }
                return Ok(());
            }
            let mut texts = Vec::new();
            for path in &files {
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("cannot read {path}"))?;
                texts.push(text);
            }
            let is_throughput = j3dai::telemetry::json::Json::parse(&texts[0])
                .ok()
                .map(|d| d.get("bench").and_then(|b| b.as_str()) == Some("throughput"))
                .unwrap_or(false);
            let cmp = if is_throughput {
                let mut thr = report::compare::ThroughputThresholds::default();
                if let Some(v) = flag(&args, "--speedup-tol").and_then(|v| v.parse().ok()) {
                    thr.speedup_pct = v;
                }
                if let Some(v) = flag(&args, "--fps-tol").and_then(|v| v.parse().ok()) {
                    thr.fps_pct = v;
                }
                let mut parsed = Vec::new();
                for (path, text) in files.iter().zip(&texts) {
                    parsed.push(report::compare::parse_bench_throughput(path, text)?);
                }
                report::compare::compare_throughput(&parsed, &thr)?
            } else {
                let mut thr = report::compare::CompareThresholds::default();
                if let Some(v) = flag(&args, "--latency-tol").and_then(|v| v.parse().ok()) {
                    thr.latency_pct = v;
                }
                if let Some(v) = flag(&args, "--power-tol").and_then(|v| v.parse().ok()) {
                    thr.power_pct = v;
                }
                if let Some(v) = flag(&args, "--topsw-tol").and_then(|v| v.parse().ok()) {
                    thr.tops_w_pct = v;
                }
                let mut parsed = Vec::new();
                for (path, text) in files.iter().zip(&texts) {
                    parsed.push(report::compare::parse_bench_ppa(path, text)?);
                }
                report::compare::compare(&parsed, &thr)?
            };
            print!("{}", cmp.table);
            for reg in &cmp.regressions {
                eprintln!("REGRESSION {}: {}", reg.model, reg.detail);
            }
            anyhow::ensure!(
                cmp.regressions.is_empty(),
                "{} regression(s) past tolerance",
                cmp.regressions.len()
            );
        }
        "table1" => {
            let rows = [
                (models::paper_mbv1(), "256x192"),
                (models::paper_mbv2(), "256x192"),
                (models::paper_seg(), "512x384"),
            ]
            .into_iter()
            .map(|(g, input)| sim::simulate(&g, &cfg).map(|r| report::table1_row(&r, &em, input)))
            .collect::<j3dai::Result<Vec<_>>>()?;
            print!("{}", report::render_table1(&rows));
        }
        "table2" => {
            let mbv2 = sim::simulate(&models::paper_mbv2(), &cfg)?;
            let mut cols = report::sony_columns();
            cols.push(report::j3dai_column(&cfg, &mbv2, &em));
            print!("{}", report::render_table2(&cols));
        }
        "fig5" => {
            print!("{}", report::render_floorplan(&area::middle_die(&cfg)));
            print!("{}", report::render_floorplan(&area::bottom_die(&cfg)));
        }
        "fig6" => print!("{}", report::render_fig6()),
        "compile" => {
            let key = flag(&args, "--model").unwrap_or_else(|| "mbv1".into());
            let g = paper_graph(&key).ok_or_else(|| anyhow::anyhow!("unknown model {key}"))?;
            let c = compiler::compile(&g, &cfg)?;
            println!("model {}: {} layers, {:.0} MMACs", c.model, g.layers.len(), g.total_macs() as f64 / 1e6);
            println!(
                "programs: {} clusters, {} bytes total; params {:.2} MB in L2, peak act {:.2} MB",
                c.cluster_programs.len(),
                c.program_bytes(),
                c.param_bytes as f64 / 1e6,
                c.peak_activation_bytes as f64 / 1e6
            );
            for m in c.layer_maps.iter().take(8) {
                println!(
                    "  {:<26} gemm {}x{}x{} tile {}x{}x{} util {:.0}% ws {} B",
                    m.name, m.m, m.k, m.n, m.bm, m.bk, m.bn, m.pe_utilization * 100.0, m.working_set_bytes
                );
            }
            if c.layer_maps.len() > 8 {
                println!("  ... {} more layers", c.layer_maps.len() - 8);
            }
        }
        "lint" => {
            if has_flag(&args, "--help") {
                println!(
                    "j3dai lint [--model mbv1|mbv2|seg|all|<artifact>] [--json] [--sarif-out F] \
                     [--flag-tsv] [--deny-warnings] [--context N]"
                );
                println!();
                println!("Compile each model and run the static program verifier over every");
                println!("cluster program: bounds/capacity, Xfer-Compute hazards, the ConvTile");
                println!("accumulator-chain protocol and program structure (docs/VERIFIER.md).");
                println!("Prints a human table (or --json), writes SARIF 2.1.0 with --sarif-out,");
                println!("and exits non-zero on any error diagnostic (--deny-warnings tightens");
                println!("the gate to warnings too). --flag-tsv enumerates TSV-crossing");
                println!("transfers as notes; --context N widens the listing window.");
                return Ok(());
            }
            let which = flag(&args, "--model").unwrap_or_else(|| "all".into());
            let keys: Vec<&str> = if which == "all" {
                vec!["mbv1", "mbv2", "seg"]
            } else {
                vec![model_key(&which)]
            };
            let policy = j3dai::verify::VerifyPolicy {
                flag_tsv: has_flag(&args, "--flag-tsv"),
                context_lines: flag(&args, "--context").and_then(|v| v.parse().ok()).unwrap_or(2),
            };
            let mut reports: Vec<(String, j3dai::verify::VerifyReport)> = Vec::new();
            for &key in &keys {
                let g = require_graph(key)?;
                let c = compiler::compile(&g, &cfg)?;
                let rep = j3dai::verify::verify_programs(&c.cluster_programs, &cfg, &policy);
                reports.push((g.name.clone(), rep));
            }
            if has_flag(&args, "--json") {
                println!("{}", j3dai::verify::sarif::to_json(&reports));
            } else {
                for (model, rep) in &reports {
                    print!("{}", report::render_diagnostics(model, rep));
                }
            }
            if let Some(path) = flag(&args, "--sarif-out") {
                std::fs::write(&path, j3dai::verify::sarif::to_sarif(&reports))
                    .with_context(|| format!("cannot write {path}"))?;
                println!("SARIF written to {path}");
            }
            let errors: usize = reports.iter().map(|(_, r)| r.error_count()).sum();
            let warnings: usize = reports.iter().map(|(_, r)| r.warning_count()).sum();
            anyhow::ensure!(errors == 0, "{errors} error diagnostic(s) across {} model(s)", keys.len());
            if has_flag(&args, "--deny-warnings") {
                anyhow::ensure!(warnings == 0, "{warnings} warning diagnostic(s) with --deny-warnings");
            }
        }
        "check-artifacts" => {
            // self-check: run every artifact on its recorded input and
            // compare against the recorded golden bytes
            let dir = flag(&args, "--dir").map(std::path::PathBuf::from).unwrap_or_else(runtime::default_artifact_dir);
            let mut rt = runtime::Runtime::new()?;
            rt.load_all(&dir)?;
            let mut bad = 0;
            for e in runtime::load_manifest(&dir)? {
                let input = std::fs::read(&e.input_path)?;
                let frame = j3dai::sim::functional::Tensor::new(e.input_shape, input);
                let out = rt.infer(&e.name, &frame)?;
                let golden = std::fs::read(&e.golden_path)?;
                let ok = out == golden;
                if !ok { bad += 1; }
                if args.iter().any(|a| a == "--dump") {
                    std::fs::write(dir.join(format!("{}.pjrt.bin", e.name)), &out)?;
                }
                let diff = out.iter().zip(&golden).filter(|(a, b)| a != b).count();
                println!("{:<24} {} ({} / {} bytes differ)", e.name, if ok { "OK" } else { "MISMATCH" }, diff, golden.len());
            }
            anyhow::ensure!(bad == 0, "{bad} artifacts mismatch");
        }
        "tiles" => return print_tile_counts(),
        "list" => {
            let entries = runtime::load_manifest(&runtime::default_artifact_dir())?;
            for e in entries {
                println!("{:<20} input {} -> output {:?}", e.name, e.input_shape, e.output_dims);
            }
        }
        "help" | "--help" | "-h" => print_help(),
        other => {
            print_help();
            anyhow::bail!("unknown command {other:?}");
        }
    }
    Ok(())
}

fn print_help() {
    println!("j3dai — J3DAI (ISLPED'25) digital-system reproduction");
    println!(
        "commands: serve | sim | trace | sample | roofline | metrics | bench-telemetry | \
         bench-ppa | bench-throughput | bench-compare | table1 | table2 | fig5 | fig6 | \
         compile | lint | list"
    );
    println!(
        "  serve --metrics-addr HOST:PORT exposes live /metrics, /trace.json, /timeseries.json"
    );
    println!("  serve --workers M fans inference out to M workers; --threads N parallelizes");
    println!("  the cluster simulation (sim/trace take --threads too; default: all cores)");
    println!("  sim/trace --profile-out F write inferno-format folded stacks (flamegraphs)");
    println!("  roofline --svg-out F writes the roofline plot as a standalone SVG");
    println!("  lint runs the static program verifier (bounds/hazard/protocol/structure)");
    println!("  bench-throughput measures parallel-sim speedup + pipeline frames/s");
    println!(
        "  sample / roofline / bench-ppa / bench-throughput / bench-compare / lint --help \
         print per-command usage"
    );
}

// (dev helper kept out of the help text: `j3dai tiles` prints per-model
// compute-tile and layer counts — used to fit the calibration constants,
// see EXPERIMENTS.md §Calibration.)
pub fn print_tile_counts() -> j3dai::Result<()> {
    let cfg = ArchConfig::j3dai();
    for key in ["mbv1", "mbv2", "seg"] {
        let g = paper_graph(key).unwrap();
        let c = compiler::compile(&g, &cfg)?;
        let tiles: usize = c
            .cluster_programs
            .iter()
            .flat_map(|p| &p.instrs)
            .filter(|i| matches!(i, j3dai::isa::Instr::ConvTile { .. } | j3dai::isa::Instr::DwTile { .. }))
            .count();
        let elem: usize = c
            .cluster_programs
            .iter()
            .flat_map(|p| &p.instrs)
            .filter(|i| matches!(i, j3dai::isa::Instr::AddTile { .. } | j3dai::isa::Instr::PoolTile { .. }))
            .count();
        println!("{key}: layers={} tiles={tiles} elem_tiles={elem}", g.layers.len());
    }
    Ok(())
}
