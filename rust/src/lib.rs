//! # j3dai — reproduction of the J3DAI 3D-stacked CMOS-image-sensor edge-AI system
//!
//! J3DAI (Tain et al., ISLPED 2025) is a 3-layer wafer-stacked image sensor
//! whose bottom die carries a tiny programmable DNN accelerator: 6 neural
//! clusters x 16 neural computing blocks x 8 PEs = 768 MAC/cycle at 200 MHz
//! in 28 nm FDSOI, fed by 5 MB of L2 SRAM split across the middle/bottom
//! dies through high-density TSVs, and programmed through the Aidge
//! post-training-quantization + mapping/scheduling export flow.
//!
//! This crate rebuilds the *digital system* of that paper as a simulated
//! substrate (we cannot tape out silicon — see DESIGN.md):
//!
//! - [`config`]   — architecture parameters (the paper's Table II knobs)
//! - [`graph`]    — quantized NN graph IR with shape/MAC accounting
//! - [`models`]   — MobileNetV1/V2 + FPN-segmentation builders (the paper's
//!   three workloads, MMAC targets 557 / 289 / 877)
//! - [`quant`]    — the INT8 post-training-quantization contract shared
//!   bit-exactly with the JAX/Pallas golden models
//! - [`isa`]      — the accelerator's macro-op instruction set + assembler
//! - [`compiler`] — the Aidge-export analog: memory placement, tiling,
//!   DMPA/DMA selection, load-masking scheduler, codegen
//! - [`sim`]      — cycle-level + functional simulator of the DNN system
//!   (PEs, NCB SRAM + local routers, clusters, AGU/AIU, DMPA/CCONNECT,
//!   DMA, L2, host)
//! - [`power`]    — activity-based energy model + die area/floorplan model
//! - [`sensor`]   — pixel-matrix / readout / ISP front-end model
//! - [`runtime`]  — PJRT client running the AOT JAX artifacts (functional
//!   golden path; python is never on the request path)
//! - [`coordinator`] — the frame-loop service tying sensor, simulator and
//!   runtime together with an FPS governor and metrics
//! - [`telemetry`] — crate-wide observability: metrics registry
//!   (Prometheus-style text), span tracing (Chrome trace-event / Perfetto
//!   export) and the shared percentile helper — see docs/OBSERVABILITY.md
//! - [`verify`]   — static program verifier: bounds/hazard/protocol/
//!   structure passes over compiled programs, SARIF export, the `lint`
//!   CLI gate — see docs/VERIFIER.md
//! - [`report`]   — renders the paper's tables/figures from measurements
//! - [`ptest`]    — tiny in-repo property-test runner (offline registry has
//!   no proptest crate)

pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod graph;
pub mod isa;
pub mod models;
pub mod power;
pub mod ptest;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sensor;
pub mod sim;
pub mod telemetry;
pub mod verify;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
