//! Tiny in-repo property-test runner (the offline registry has no proptest
//! crate). Seeded xorshift-based case generation, fixed case count, and a
//! failure report that prints the seed so cases replay deterministically.

/// Deterministic PRNG for property inputs (xorshift64*).
#[derive(Debug, Clone)]
pub struct Gen {
    state: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { state: seed.max(1) }
    }

    pub fn u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [lo, hi] (inclusive).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.u64() % (hi - lo + 1)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    pub fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        (self.range(0, (hi - lo) as u64) as i64 + lo as i64) as i32
    }

    pub fn u8(&mut self) -> u8 {
        (self.u64() >> 56) as u8
    }

    pub fn bool(&mut self) -> bool {
        self.u64() & 1 == 1
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len() - 1)]
    }
}

/// Run `cases` property checks; panics with the failing seed on error.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: u32, mut prop: F) {
    for case in 0..cases {
        let seed = 0x9E37_79B9_7F4A_7C15u64 ^ (case as u64).wrapping_mul(0xD134_2543_DE82_EF95);
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            panic!("property `{name}` failed on case {case} (seed {seed:#x}): {e:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_is_deterministic() {
        let mut a = Gen::new(7);
        let mut b = Gen::new(7);
        for _ in 0..100 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn range_is_inclusive_and_bounded() {
        let mut g = Gen::new(3);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..2000 {
            let v = g.range(2, 5);
            assert!((2..=5).contains(&v));
            saw_lo |= v == 2;
            saw_hi |= v == 5;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("counter", 25, |_| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property `boom` failed")]
    fn check_reports_seed() {
        check("boom", 5, |g| assert!(g.u64() % 2 == 0 || g.u64() % 2 == 1, "never"));
        check("boom", 5, |_| panic!("kaboom"));
    }
}
