//! Post-training quantization pipeline — the Aidge flow of §III-C1:
//! "Post-training quantization converts high-precision floating-point
//! models (e.g. FP32) ... into low-precision fixed-point representations
//! (e.g. INT8) ... This process involves calibrating the model using a
//! representative dataset to determine optimal scaling factors for weights
//! and activations."
//!
//! This module runs that flow end to end on a float CNN: a float reference
//! interpreter, per-tensor calibration over representative frames, weight
//! quantization, requant-parameter folding ([`super::quantize_multiplier`])
//! and an INT8 execution whose outputs are compared against the float
//! reference (the quantization-error metric Aidge reports).

use crate::graph::{Graph, Op, Shape, INPUT};
use crate::quant::{apply_multiplier, calibrate_minmax, quantize_multiplier};

/// A float tensor in HWC layout.
#[derive(Debug, Clone)]
pub struct FTensor {
    pub shape: Shape,
    pub data: Vec<f32>,
}

impl FTensor {
    pub fn new(shape: Shape, data: Vec<f32>) -> Self {
        assert_eq!(shape.elems(), data.len());
        FTensor { shape, data }
    }

    fn at(&self, y: usize, x: usize, c: usize) -> f32 {
        self.data[(y * self.shape.w + x) * self.shape.c + c]
    }
}

/// Float parameters for one layer.
#[derive(Debug, Clone)]
pub struct FloatLayerParams {
    pub weights: Vec<f32>,
    pub bias: Vec<f32>,
}

/// Deterministic float model parameters (truncated-normal-ish from the
/// shared PRNG streams, scaled by fan-in like standard initializers).
pub fn float_params(name: &str, fan_in: usize, w_len: usize, n_out: usize) -> FloatLayerParams {
    let scale = (2.0 / fan_in as f32).sqrt();
    let w = super::weights::gen_weights_i8(&format!("{name}/w"), w_len);
    let b = super::weights::gen_bias_i32(name, n_out);
    FloatLayerParams {
        weights: w.iter().map(|&v| v as f32 / 64.0 * scale).collect(),
        bias: b.iter().map(|&v| v as f32 / 1024.0 * 0.1).collect(),
    }
}

/// Run the float reference forward; returns every layer's output.
pub fn run_float(g: &Graph, input: &FTensor) -> Vec<FTensor> {
    let mut outs: Vec<FTensor> = Vec::with_capacity(g.layers.len());
    for l in &g.layers {
        let get = |i: usize| -> &FTensor { if i == INPUT { input } else { &outs[i] } };
        let x = get(l.inputs[0]);
        let y = match &l.op {
            Op::Conv { kh, kw, cout, stride, relu } => {
                let cin = x.shape.c;
                let p = float_params(&l.name, kh * kw * cin, kh * kw * cin * cout, *cout);
                conv_f32(x, &p, *kh, *kw, *cout, *stride, *relu)
            }
            Op::DwConv { stride } => {
                let c = x.shape.c;
                let p = float_params(&l.name, 9, 9 * c, c);
                dwconv_f32(x, &p, *stride)
            }
            Op::Dense { out } => {
                let k = x.shape.elems();
                let p = float_params(&l.name, k, k * out, *out);
                dense_f32(x, &p, *out)
            }
            Op::Add => {
                let b = get(l.inputs[1]);
                FTensor::new(x.shape, x.data.iter().zip(&b.data).map(|(a, c)| (a + c) / 2.0).collect())
            }
            Op::GlobalAvgPool => {
                let n = (x.shape.h * x.shape.w) as f32;
                let mut out = vec![0f32; x.shape.c];
                for (ch, o) in out.iter_mut().enumerate() {
                    for y in 0..x.shape.h {
                        for xx in 0..x.shape.w {
                            *o += x.at(y, xx, ch);
                        }
                    }
                    *o /= n;
                }
                FTensor::new(Shape::new(1, 1, x.shape.c), out)
            }
            Op::Upsample2x { to_h, to_w } => {
                let c = x.shape.c;
                let mut out = vec![0f32; to_h * to_w * c];
                for y in 0..*to_h {
                    for xx in 0..*to_w {
                        for ch in 0..c {
                            out[(y * to_w + xx) * c + ch] = x.at(y / 2, xx / 2, ch);
                        }
                    }
                }
                FTensor::new(Shape::new(*to_h, *to_w, c), out)
            }
            Op::NluSigmoid => FTensor::new(x.shape, x.data.iter().map(|v| 1.0 / (1.0 + (-v).exp())).collect()),
        };
        outs.push(y);
    }
    outs
}

fn conv_f32(x: &FTensor, p: &FloatLayerParams, kh: usize, kw: usize, cout: usize, stride: usize, relu: bool) -> FTensor {
    let (h, w, cin) = (x.shape.h, x.shape.w, x.shape.c);
    let (ph, pw) = ((kh - 1) / 2, (kw - 1) / 2);
    let oh = (h + 2 * ph - kh) / stride + 1;
    let ow = (w + 2 * pw - kw) / stride + 1;
    let mut out = vec![0f32; oh * ow * cout];
    for oy in 0..oh {
        for ox in 0..ow {
            for co in 0..cout {
                let mut acc = p.bias[co];
                for dy in 0..kh {
                    let yy = (oy * stride + dy) as isize - ph as isize;
                    if yy < 0 || yy >= h as isize {
                        continue;
                    }
                    for dx in 0..kw {
                        let xx = (ox * stride + dx) as isize - pw as isize;
                        if xx < 0 || xx >= w as isize {
                            continue;
                        }
                        for ci in 0..cin {
                            acc += x.at(yy as usize, xx as usize, ci) * p.weights[((dy * kw + dx) * cin + ci) * cout + co];
                        }
                    }
                }
                out[(oy * ow + ox) * cout + co] = if relu { acc.max(0.0) } else { acc };
            }
        }
    }
    FTensor::new(Shape::new(oh, ow, cout), out)
}

fn dwconv_f32(x: &FTensor, p: &FloatLayerParams, stride: usize) -> FTensor {
    let (h, w, c) = (x.shape.h, x.shape.w, x.shape.c);
    let oh = (h - 1) / stride + 1;
    let ow = (w - 1) / stride + 1;
    let mut out = vec![0f32; oh * ow * c];
    for oy in 0..oh {
        for ox in 0..ow {
            for ch in 0..c {
                let mut acc = p.bias[ch];
                for dy in 0..3 {
                    let yy = (oy * stride + dy) as isize - 1;
                    if yy < 0 || yy >= h as isize {
                        continue;
                    }
                    for dx in 0..3 {
                        let xx = (ox * stride + dx) as isize - 1;
                        if xx < 0 || xx >= w as isize {
                            continue;
                        }
                        acc += x.at(yy as usize, xx as usize, ch) * p.weights[(dy * 3 + dx) * c + ch];
                    }
                }
                out[(oy * ow + ox) * c + ch] = acc.max(0.0);
            }
        }
    }
    FTensor::new(Shape::new(oh, ow, c), out)
}

fn dense_f32(x: &FTensor, p: &FloatLayerParams, n_out: usize) -> FTensor {
    let mut out = vec![0f32; n_out];
    for (co, o) in out.iter_mut().enumerate() {
        let mut acc = p.bias[co];
        for (ci, &v) in x.data.iter().enumerate() {
            acc += v * p.weights[ci * n_out + co];
        }
        *o = acc;
    }
    FTensor::new(Shape::new(1, 1, n_out), out)
}

/// Per-layer quantization record produced by calibration.
#[derive(Debug, Clone)]
pub struct QLayer {
    pub name: String,
    /// activation scale/zero-point at this layer's output
    pub scale: f32,
    pub zp: i32,
    /// weight scale (per-tensor symmetric int8)
    pub w_scale: f32,
    /// folded requant pair: real = s_in * s_w / s_out
    pub mult: i32,
    pub shift: u32,
}

/// Calibrated, quantized model.
#[derive(Debug, Clone)]
pub struct QuantizedModel {
    pub layers: Vec<QLayer>,
    pub input_scale: f32,
    pub input_zp: i32,
}

/// Calibrate over representative frames (the Aidge calibration step) and
/// fold scales into fixed-point requant parameters.
pub fn calibrate(g: &Graph, frames: &[FTensor], percentile: f64) -> QuantizedModel {
    assert!(!frames.is_empty());
    // collect activation samples per layer across frames
    let mut samples: Vec<Vec<f32>> = vec![Vec::new(); g.layers.len()];
    let mut input_samples = Vec::new();
    for f in frames {
        input_samples.extend_from_slice(&f.data);
        for (li, t) in run_float(g, f).into_iter().enumerate() {
            // subsample to bound memory
            samples[li].extend(t.data.iter().step_by(7).copied());
        }
    }
    let (in_scale, in_zp) = calibrate_minmax(&input_samples, percentile);

    let mut layers = Vec::with_capacity(g.layers.len());
    let mut prev_scale = in_scale;
    for (li, l) in g.layers.iter().enumerate() {
        let (scale, zp) = calibrate_minmax(&samples[li], percentile);
        let (w_scale, mult, shift) = match &l.op {
            Op::Conv { kh, kw, cout, .. } => {
                let cin = if l.inputs[0] == INPUT { g.input.c } else { g.layers[l.inputs[0]].out_shape.c };
                let p = float_params(&l.name, kh * kw * cin, kh * kw * cin * cout, *cout);
                fold(&p.weights, prev_scale, scale)
            }
            Op::DwConv { .. } => {
                let c = l.out_shape.c;
                let p = float_params(&l.name, 9, 9 * c, c);
                fold(&p.weights, prev_scale, scale)
            }
            Op::Dense { out } => {
                let k = if l.inputs[0] == INPUT { g.input.elems() } else { g.layers[l.inputs[0]].out_shape.elems() };
                let p = float_params(&l.name, k, k * out, *out);
                fold(&p.weights, prev_scale, scale)
            }
            _ => (1.0, 0, 0),
        };
        layers.push(QLayer { name: l.name.clone(), scale, zp, w_scale, mult, shift });
        prev_scale = scale;
    }
    QuantizedModel { layers, input_scale: in_scale, input_zp: in_zp }
}

fn fold(weights: &[f32], s_in: f32, s_out: f32) -> (f32, i32, u32) {
    let w_max = weights.iter().fold(0f32, |m, &v| m.max(v.abs())).max(f32::MIN_POSITIVE);
    let w_scale = w_max / 127.0;
    let real = (s_in as f64 * w_scale as f64) / s_out as f64;
    // requant multipliers must be < 1; the calibrated scales of a sane
    // network guarantee it, clamp defensively otherwise
    let real = real.clamp(1e-9, 0.999_999);
    let (mult, shift) = quantize_multiplier(real);
    (w_scale, mult, shift)
}

/// Quantization error metrics of an INT8-executed layer vs float reference.
#[derive(Debug, Clone, Copy)]
pub struct QuantError {
    pub mean_abs: f64,
    pub max_abs: f64,
    /// signal-to-quantization-noise ratio in dB
    pub sqnr_db: f64,
}

/// Execute the quantized model on a frame (INT8 semantics with the folded
/// parameters) and measure the error of the final output vs float.
pub fn quantized_vs_float(g: &Graph, qm: &QuantizedModel, frame: &FTensor) -> QuantError {
    let float_out = run_float(g, frame).pop().unwrap();

    // quantize input
    let q_in: Vec<u8> = frame
        .data
        .iter()
        .map(|&v| ((v / qm.input_scale).round() as i32 + qm.input_zp).clamp(0, 255) as u8)
        .collect();

    // INT8 forward with the calibrated parameters (conv/dw/dense only paths
    // exercised by the test graph; elementwise ops pass through rescaled)
    let mut cur: Vec<u8> = q_in;
    let mut cur_shape = g.input;
    let mut cur_scale = qm.input_scale;
    let mut cur_zp = qm.input_zp;
    for (li, l) in g.layers.iter().enumerate() {
        let q = &qm.layers[li];
        match &l.op {
            Op::Conv { kh, kw, cout, stride, relu } => {
                let cin = cur_shape.c;
                let p = float_params(&l.name, kh * kw * cin, kh * kw * cin * cout, *cout);
                let wq: Vec<i8> = p.weights.iter().map(|&v| ((v / q.w_scale).round() as i32).clamp(-127, 127) as i8).collect();
                // bias folded to the int32 accumulator domain: b / (s_in*s_w)
                let bq: Vec<i32> = p.bias.iter().map(|&v| (v / (cur_scale * q.w_scale)).round() as i32).collect();
                let (ph, pw) = ((kh - 1) / 2, (kw - 1) / 2);
                let oh = (cur_shape.h + 2 * ph - kh) / stride + 1;
                let ow = (cur_shape.w + 2 * pw - kw) / stride + 1;
                let mut out = vec![0u8; oh * ow * cout];
                for oy in 0..oh {
                    for ox in 0..ow {
                        for co in 0..*cout {
                            let mut acc = bq[co];
                            for dy in 0..*kh {
                                let yy = (oy * stride + dy) as isize - ph as isize;
                                if yy < 0 || yy >= cur_shape.h as isize {
                                    continue;
                                }
                                for dx in 0..*kw {
                                    let xx = (ox * stride + dx) as isize - pw as isize;
                                    if xx < 0 || xx >= cur_shape.w as isize {
                                        continue;
                                    }
                                    for ci in 0..cin {
                                        let a = cur[((yy as usize) * cur_shape.w + xx as usize) * cin + ci] as i32 - cur_zp;
                                        acc += a * wq[((dy * kw + dx) * cin + ci) * cout + co] as i32;
                                    }
                                }
                            }
                            let y = apply_multiplier(acc, q.mult, q.shift) + q.zp;
                            let lo = if *relu { q.zp } else { 0 };
                            out[(oy * ow + ox) * cout + co] = y.clamp(lo, 255) as u8;
                        }
                    }
                }
                cur = out;
                cur_shape = Shape::new(oh, ow, *cout);
            }
            Op::GlobalAvgPool => {
                let n = (cur_shape.h * cur_shape.w) as i64;
                let mut out = vec![0u8; cur_shape.c];
                for (ch, o) in out.iter_mut().enumerate() {
                    let mut s = 0i64;
                    for y in 0..cur_shape.h {
                        for x in 0..cur_shape.w {
                            s += cur[(y * cur_shape.w + x) * cur_shape.c + ch] as i64;
                        }
                    }
                    *o = ((s + n / 2) / n).clamp(0, 255) as u8;
                }
                cur = out;
                cur_shape = Shape::new(1, 1, cur_shape.c);
            }
            _ => unimplemented!("PTQ demo graph uses conv/pool only: {}", l.name),
        }
        cur_scale = q.scale;
        cur_zp = q.zp;
    }

    // dequantize and compare
    let deq: Vec<f64> = cur.iter().map(|&v| (v as i32 - cur_zp) as f64 * cur_scale as f64).collect();
    let mut mean = 0.0;
    let mut max: f64 = 0.0;
    let mut sig = 0.0;
    let mut noise = 0.0;
    for (d, f) in deq.iter().zip(&float_out.data) {
        let e = (d - *f as f64).abs();
        mean += e;
        max = max.max(e);
        sig += (*f as f64) * (*f as f64);
        noise += e * e;
    }
    mean /= deq.len() as f64;
    let sqnr_db = 10.0 * (sig / noise.max(1e-12)).log10();
    QuantError { mean_abs: mean, max_abs: max, sqnr_db }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    fn demo_graph() -> Graph {
        let mut g = Graph::new("ptq", Shape::new(12, 16, 3));
        let c0 = g.push("ptq/c0", Op::Conv { kh: 3, kw: 3, cout: 8, stride: 2, relu: true }, vec![INPUT]);
        let c1 = g.push("ptq/c1", Op::Conv { kh: 1, kw: 1, cout: 16, stride: 1, relu: true }, vec![c0]);
        g.push("ptq/pool", Op::GlobalAvgPool, vec![c1]);
        g
    }

    fn frames(g: &Graph, n: u64) -> Vec<FTensor> {
        (0..n)
            .map(|i| {
                let px = crate::sensor::PixelArray::new(100 + i);
                let t = px.capture(i, g.input);
                FTensor::new(g.input, t.data.iter().map(|&v| v as f32 / 255.0).collect())
            })
            .collect()
    }

    #[test]
    fn float_reference_is_deterministic() {
        let g = demo_graph();
        let f = &frames(&g, 1)[0];
        let a = run_float(&g, f).pop().unwrap();
        let b = run_float(&g, f).pop().unwrap();
        assert_eq!(a.data, b.data);
        assert!(a.data.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn calibration_produces_sane_scales() {
        let g = demo_graph();
        let qm = calibrate(&g, &frames(&g, 4), 0.999);
        assert!(qm.input_scale > 0.0);
        for q in &qm.layers {
            assert!(q.scale > 0.0, "{}", q.name);
            assert!((0..=255).contains(&q.zp), "{}", q.name);
        }
        // conv layers got folded requant params
        assert!(qm.layers[0].mult > 0 && qm.layers[0].shift >= 24);
    }

    #[test]
    fn int8_tracks_float_within_quantization_noise() {
        // The Aidge PTQ claim: INT8 deployment with "minimal loss of
        // precision". SQNR of the final output should be solidly positive.
        let g = demo_graph();
        let fs = frames(&g, 6);
        let qm = calibrate(&g, &fs[..4], 0.999);
        for f in &fs[4..] {
            let e = quantized_vs_float(&g, &qm, f);
            assert!(e.sqnr_db > 6.0, "SQNR too low: {e:?}"); // ~9 dB measured
            assert!(e.mean_abs < 0.05, "mean abs err too high: {e:?}");
        }
    }

    #[test]
    fn tighter_percentile_clips_outliers() {
        let g = demo_graph();
        let fs = frames(&g, 3);
        let full = calibrate(&g, &fs, 1.0);
        let clipped = calibrate(&g, &fs, 0.95);
        // clipping the range can only shrink (or keep) the scale
        assert!(clipped.layers[0].scale <= full.layers[0].scale + 1e-9);
    }
}
