//! Post-training quantization contract — the Rust twin of
//! `python/compile/quantize.py`.
//!
//! The paper deploys FP32-trained models through Aidge's post-training
//! quantization to uint8 activations / int8 weights with fixed-point
//! requantization. This module holds the arithmetic that the functional
//! simulator, the compiler's codegen and the JAX golden models all share.
//!
//! Activations: uint8 affine (zero point 128 in the synthetic stack) — the
//! zero-point-subtracted operand is a 9-bit signed value, exactly the J3DAI
//! PE multiplier width. Weights: int8 symmetric. Accumulation: int32 (the
//! PE's 32-bit accumulator). Requantization:
//!
//! ```text
//! y = clamp(((acc * M + (1 << (shift-1))) >> shift) + zp_out, lo, hi)
//! ```
//!
//! with the product in int64 and `>>` arithmetic — identical in both
//! languages, so no rounding-mode mismatch is possible.

pub mod ptq;
pub mod weights;

/// Fixed post-scaling shift used across the stack.
pub const SHIFT: u32 = 24;
/// Global synthetic activation zero point.
pub const ZP: i32 = 128;

/// Requantization parameters for one layer output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Requant {
    /// int32 fixed-point multiplier.
    pub mult: i32,
    /// Right shift applied after the multiply.
    pub shift: u32,
    /// Output zero point.
    pub zp_out: i32,
    /// Post-activation clamp low (uint8 domain). ReLU == `zp_out`.
    pub act_min: i32,
    /// Post-activation clamp high. ReLU6 == q(6.0) == 224 here.
    pub act_max: i32,
}

impl Requant {
    /// Apply the contract to one int32 accumulator value. The whole chain
    /// stays in i64 (like the numpy oracle), so even out-of-contract
    /// (mult, shift) pairs clamp monotonically instead of wrapping.
    #[inline(always)]
    pub fn apply(&self, acc: i32) -> u8 {
        let prod = acc as i64 * self.mult as i64 + (1i64 << (self.shift - 1));
        let y = (prod >> self.shift) + self.zp_out as i64;
        y.clamp(self.act_min as i64, self.act_max as i64) as u8
    }
}

/// Deterministic requant parameters for a synthetic layer of reduction
/// depth `k` — must match `quantize.requant_for_reduction` bit-for-bit
/// (same f64 expression, same rounding).
pub fn requant_for_reduction(k: usize, relu: bool, relu6: bool) -> Requant {
    let k = k.max(1) as f64;
    let scale = 1.0 / (k.sqrt() * 48.0);
    let mult = ((scale * (1u64 << SHIFT) as f64).round() as i64).max(1) as i32;
    let zp = ZP;
    Requant {
        mult,
        shift: SHIFT,
        zp_out: zp,
        act_min: if relu { zp } else { 0 },
        act_max: if relu6 { 224 } else { 255 },
    }
}

/// Parameters of the quantized residual add:
/// `y = clamp((((a-zpa)*ma + (b-zpb)*mb + rnd) >> shift) + zpo, lo, hi)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QAdd {
    pub zp_a: i32,
    pub zp_b: i32,
    pub mult_a: i32,
    pub mult_b: i32,
    pub shift: u32,
    pub zp_out: i32,
    pub act_min: i32,
    pub act_max: i32,
}

impl QAdd {
    /// The synthetic-stack default: average the two branches.
    pub fn default_params() -> Self {
        let half = 1i32 << (SHIFT - 1);
        QAdd { zp_a: ZP, zp_b: ZP, mult_a: half, mult_b: half, shift: SHIFT, zp_out: ZP, act_min: 0, act_max: 255 }
    }

    #[inline(always)]
    pub fn apply(&self, a: u8, b: u8) -> u8 {
        let av = (a as i32 - self.zp_a) as i64;
        let bv = (b as i32 - self.zp_b) as i64;
        let sum = av * self.mult_a as i64 + bv * self.mult_b as i64 + (1i64 << (self.shift - 1));
        let y = (sum >> self.shift) as i32 + self.zp_out;
        y.clamp(self.act_min, self.act_max) as u8
    }
}

/// Post-training calibration over a representative activation sample —
/// the Aidge "calibrating the model using a representative dataset" step.
/// Returns the affine (scale, zero_point) for a uint8 target using min/max
/// observation with optional percentile clipping.
pub fn calibrate_minmax(samples: &[f32], percentile: f64) -> (f32, i32) {
    assert!(!samples.is_empty(), "empty calibration sample");
    let mut v: Vec<f32> = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let lo_idx = (((1.0 - percentile) / 2.0) * (v.len() - 1) as f64).round() as usize;
    let hi_idx = ((1.0 - (1.0 - percentile) / 2.0) * (v.len() - 1) as f64).round() as usize;
    let (lo, hi) = (v[lo_idx].min(0.0), v[hi_idx].max(0.0));
    let scale = ((hi - lo) / 255.0).max(f32::MIN_POSITIVE);
    let zp = (-lo / scale).round() as i32;
    (scale, zp.clamp(0, 255))
}

/// Fold a float rescale factor into the fixed-point (mult, shift) pair the
/// hardware requant path executes — the Aidge export's final step.
pub fn quantize_multiplier(real: f64) -> (i32, u32) {
    assert!(real > 0.0 && real < 1.0, "requant multiplier must be in (0,1): {real}");
    let mut shift = 0u32;
    let mut r = real;
    // normalize into [0.5, 1.0) like gemmlowp, then fix the shift at >= 24
    while r < 0.5 {
        r *= 2.0;
        shift += 1;
    }
    let q = (r * (1u64 << 31) as f64).round() as i64;
    let (q, shift) = if q == (1i64 << 31) { (q / 2, shift.saturating_sub(1)) } else { (q, shift) };
    (q as i32, shift + 31)
}

/// Apply a (mult, shift) pair from [`quantize_multiplier`] to an i32 value.
pub fn apply_multiplier(acc: i32, mult: i32, shift: u32) -> i32 {
    let prod = acc as i64 * mult as i64 + (1i64 << (shift - 1));
    (prod >> shift) as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requant_matches_python_semantics() {
        // Hand-checked vectors of the shared formula.
        let rq = Requant { mult: 1 << 23, shift: 24, zp_out: 128, act_min: 0, act_max: 255 };
        assert_eq!(rq.apply(0), 128);
        assert_eq!(rq.apply(2), 129); // 2*2^23 + 2^23 >> 24 = 1.25 -> 1
        assert_eq!(rq.apply(-2), 127);
        assert_eq!(rq.apply(1), 129); // 0.5 + 0.5 -> rounds toward +inf
        assert_eq!(rq.apply(-1), 128); // -0.5 + 0.5 -> 0
        assert_eq!(rq.apply(i32::MAX / 2), 255);
        assert_eq!(rq.apply(i32::MIN / 2), 0);
    }

    #[test]
    fn requant_for_reduction_known_values() {
        // k=9 -> scale=1/(3*48) -> mult=round(2^24/144)=116508
        let rq = requant_for_reduction(9, true, false);
        assert_eq!(rq.mult, 116_508);
        assert_eq!(rq.shift, 24);
        assert_eq!(rq.act_min, 128);
        assert_eq!(rq.act_max, 255);
        let rq = requant_for_reduction(27, false, false);
        assert_eq!(rq.act_min, 0);
        // relu6 clamps at the synthetic q(6.0)
        assert_eq!(requant_for_reduction(27, true, true).act_max, 224);
    }

    #[test]
    fn qadd_identity_at_zero_point() {
        let p = QAdd::default_params();
        assert_eq!(p.apply(128, 128), 128);
        assert_eq!(p.apply(130, 130), 130); // avg of equal values is the value
        // (-128 + 127)/2 = -0.5, rounding bias pushes to 0 -> zp
        assert_eq!(p.apply(0, 255), 128);
    }

    #[test]
    fn qadd_is_commutative() {
        let p = QAdd::default_params();
        for a in (0u16..=255).step_by(17) {
            for b in (0u16..=255).step_by(13) {
                assert_eq!(p.apply(a as u8, b as u8), p.apply(b as u8, a as u8));
            }
        }
    }

    #[test]
    fn calibration_covers_range() {
        let samples: Vec<f32> = (-100..=100).map(|v| v as f32 / 10.0).collect();
        let (scale, zp) = calibrate_minmax(&samples, 1.0);
        assert!((scale - 20.0 / 255.0).abs() < 1e-6);
        assert!((127..=128).contains(&zp));
    }

    #[test]
    fn quantize_multiplier_roundtrip() {
        for real in [0.4999, 0.25, 0.1, 0.003, 1.0 / 144.0] {
            let (m, s) = quantize_multiplier(real);
            let approx = m as f64 / (1u64 << s.min(63)) as f64 * if s > 63 { 0.0 } else { 1.0 };
            if s <= 62 {
                assert!((approx - real).abs() / real < 1e-6, "real={real} m={m} s={s}");
            }
            // applying to a mid-size accumulator is close to real * acc
            let acc = 1_000_000i32;
            let got = apply_multiplier(acc, m, s);
            let want = (acc as f64 * real).round() as i32;
            assert!((got - want).abs() <= 1, "real={real} got={got} want={want}");
        }
    }
}
