//! Deterministic synthetic weight streams — the Rust twin of
//! `python/compile/weights.py`.
//!
//! Golden-model parameters are drawn from named splitmix64 streams seeded by
//! FNV-1a of the tensor name, so the JAX models and the Rust functional
//! simulator materialize identical tensors without any weight files:
//!
//! ```text
//! seed    = fnv1a64(tensor_name)
//! z_i     = splitmix64(seed + (i+1) * GAMMA)
//! int8  w = (z_i >> 40) % 128 - 64
//! int32 b = (z_i >> 32) % 2048 - 1024      (stream name + "/bias")
//! uint8 x = (z_i >> 56)                    (stream name + "/input")
//! ```

const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
const FNV_PRIME: u64 = 0x100_0000_01B3;
const GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// FNV-1a 64-bit hash of a tensor name.
pub fn fnv1a64(name: &str) -> u64 {
    let mut h = FNV_OFFSET;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Sequential splitmix64 generator.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Seed from a tensor name.
    pub fn from_name(name: &str) -> Self {
        Self::new(fnv1a64(name))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GAMMA);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// int8 weights in [-64, 63] for the named tensor.
pub fn gen_weights_i8(name: &str, n: usize) -> Vec<i8> {
    let mut rng = SplitMix64::from_name(name);
    (0..n).map(|_| (((rng.next_u64() >> 40) % 128) as i64 - 64) as i8).collect()
}

/// int32 biases in [-1024, 1023] for the named tensor.
pub fn gen_bias_i32(name: &str, n: usize) -> Vec<i32> {
    let mut rng = SplitMix64::from_name(&format!("{name}/bias"));
    (0..n).map(|_| (((rng.next_u64() >> 32) % 2048) as i64 - 1024) as i32).collect()
}

/// uint8 synthetic input frame for the named stream.
pub fn gen_input_u8(name: &str, n: usize) -> Vec<u8> {
    let mut rng = SplitMix64::from_name(&format!("{name}/input"));
    (0..n).map(|_| (rng.next_u64() >> 56) as u8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_vectors() {
        // Pinned against python/tests/test_weights_parity.py.
        assert_eq!(fnv1a64(""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(fnv1a64("a"), 0xAF63_DC4C_8601_EC8C);
    }

    #[test]
    fn pinned_first_draws_match_python() {
        // Twin of test_weights_parity.py::test_pinned_first_draws.
        assert_eq!(gen_weights_i8("pin", 4), vec![23, 16, -51, 40]);
        assert_eq!(gen_bias_i32("pin", 4), vec![-244, 620, 735, -874]);
        assert_eq!(gen_input_u8("pin", 4), vec![65, 45, 205, 4]);
    }

    #[test]
    fn ranges_hold() {
        let w = gen_weights_i8("range-test", 1000);
        assert!(w.iter().all(|&v| (-64..=63).contains(&v)));
        let b = gen_bias_i32("range-test", 1000);
        assert!(b.iter().all(|&v| (-1024..=1023).contains(&v)));
    }

    #[test]
    fn name_sensitivity() {
        assert_ne!(gen_weights_i8("name-a", 64), gen_weights_i8("name-b", 64));
        assert_eq!(gen_weights_i8("name-a", 64), gen_weights_i8("name-a", 64));
    }
}
