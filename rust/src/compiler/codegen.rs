//! Codegen — emit per-cluster macro-op programs from the layer maps.
//!
//! Loop structure per GEMM layer (output-stationary, the paper's
//! "computing process" with masked parameter loads):
//!
//! ```text
//! for tm in M-tiles:              # rows of this cluster's slice
//!     dmpa.load act(tm)           # xfer engine — overlaps previous tile
//!     for tn in N-tiles:
//!         for tk in K-tiles:
//!             dmpa.load w(tn,tk)  # prefetched ahead of the MACs
//!             conv.tile bm x bk x bn
//!     sync                        # step boundary: max(xfer, compute)
//! dmpa.store out
//! ```
//!
//! With the AIU enabled, one `aiu.loop` instruction per loop level replaces
//! the per-tile routing configuration; with it disabled a `route.cfg` is
//! emitted before every tile — reproducing the §III-B2 program-footprint
//! and ops/cycle claims.
//!
//! ## Address semantics
//!
//! Transfers carry real addresses: the L2 side indexes the placement's
//! unified arena ([`ArchConfig::l2_arena_bytes`], bases from
//! [`Placement`]), the local side indexes this cluster's flat NCB-SRAM
//! window, laid out per layer by a bump allocator ([`LocalArena`]) —
//! disjoint resident slots, ping-ponged weight buffers. The simulator's
//! timing/energy model depends only on byte counts and [`Space`] tags
//! (addresses are free), so the `Space` selection keeps the legacy
//! size-heuristic placement the PPA baselines were calibrated against
//! while the *addresses* come from the real placement. Buffers larger
//! than residency stream through the multi-banked SRAM: their windows
//! intentionally run past the SRAM top, which the verifier reports as a
//! `bounds.local-spill` warning, not an error. Every emitted program is
//! checked by the static verifier in debug builds (see docs/VERIFIER.md).

use crate::config::ArchConfig;
use crate::graph::{Graph, Op, INPUT};
use crate::isa::{Instr, Program, Space};

use super::mapper::{LayerMap, Placement};

/// Which space activations are tagged with for transfer accounting. The
/// energy/TSV model keys on this tag; activation traffic is charged to
/// the bottom-die partition (where the placement keeps the hot arena).
fn act_space(_g: &Graph, _li: usize) -> Space {
    Space::L2Bottom
}

/// Which L2 partition a layer's parameters were placed in: big late-model
/// tensors spill to the middle die. The tag uses the same size heuristic
/// the PPA baselines were calibrated against; the transfer *addresses*
/// come from the placement stage.
fn param_space(middle: bool) -> Space {
    if middle { Space::L2Middle } else { Space::L2Bottom }
}

/// Clamp a byte count to the ISA's u32 field.
fn b32(bytes: u64) -> u32 {
    bytes.min(u32::MAX as u64) as u32
}

/// Clamp an L2 window so it stays inside the placement arena — the base
/// is authoritative, the clamp only matters for streamed buffers whose
/// logical extent outruns the allocation.
fn l2win(base: u64, bytes: u32, arena: u32) -> u32 {
    b32(base).min(arena.saturating_sub(bytes))
}

/// Per-layer, per-cluster local-SRAM layout: a bump allocator over the
/// cluster's flat NCB-SRAM window. Successful allocations are disjoint;
/// requests that no longer fit return a window that deliberately runs
/// past the SRAM top — the verifier treats such windows as streamed
/// (bounds warning, no hazard tracking) rather than resident.
struct LocalArena {
    cursor: u32,
    cap: u32,
}

impl LocalArena {
    fn new(cap: u32) -> LocalArena {
        LocalArena { cursor: 0, cap }
    }

    fn alloc(&mut self, bytes: u32) -> u32 {
        if bytes > 0 && self.cursor.checked_add(bytes).is_some_and(|end| end <= self.cap) {
            let addr = self.cursor;
            self.cursor += bytes;
            addr
        } else {
            // streamed: base stays in range, the extent exceeds the top
            self.cursor.min(self.cap.saturating_sub(1))
        }
    }
}

/// Emit the load instruction for the selected transfer engine.
fn load_at(use_dmpa: bool, src: Space, src_addr: u32, dst_addr: u32, bytes: u64) -> Instr {
    let bytes = b32(bytes);
    if use_dmpa {
        Instr::DmpaLoad { src, src_addr, dst_addr, bytes }
    } else {
        Instr::DmaLoad { src, src_addr, dst_addr, bytes }
    }
}

fn store_at(use_dmpa: bool, dst: Space, dst_addr: u32, src_addr: u32, bytes: u64) -> Instr {
    let bytes = b32(bytes);
    if use_dmpa {
        Instr::DmpaStore { dst, dst_addr, src_addr, bytes }
    } else {
        Instr::DmaStore { dst, dst_addr, src_addr, bytes }
    }
}

/// Split `n` into `parts` contiguous chunks (first chunks get the remainder).
fn chunks(n: usize, parts: usize) -> Vec<usize> {
    super::mapper::split_rows(n, parts)
}

/// L2 base addresses for one layer (from the placement stage).
struct Bases {
    /// This layer's input activation buffer.
    input: u64,
    /// This layer's parameter block (0 for parameterless ops).
    param: u64,
    /// This layer's output activation buffer.
    out: u64,
    /// Arena capacity every L2 window is clamped against.
    arena: u32,
}

/// Emit all cluster programs for the graph.
pub fn emit(
    g: &Graph,
    cfg: &ArchConfig,
    maps: &[LayerMap],
    placement: &Placement,
) -> crate::Result<Vec<Program>> {
    let mut programs: Vec<Program> = (0..cfg.clusters).map(|_| Program::default()).collect();
    let lanes = cfg.cluster_macs_per_cycle() as usize;
    let local_cap = b32(cfg.cluster_local_bytes() as u64);
    let arena = b32(cfg.l2_arena_bytes() as u64);

    for map in maps {
        let l = &g.layers[map.layer];
        // telemetry marker: attribute the following instructions to this
        // layer in traced simulation (free on both engines)
        for prog in programs.iter_mut() {
            prog.instrs.push(Instr::LayerMark { id: map.layer as u32 });
        }
        let in_shape = if l.inputs[0] == INPUT { g.input } else { g.layers[l.inputs[0]].out_shape };
        let bases = Bases {
            input: if l.inputs[0] == INPUT {
                placement.input.addr as u64
            } else {
                placement.activations[l.inputs[0]].addr as u64
            },
            param: placement.params[map.layer].as_ref().map_or(0, |a| a.addr as u64),
            out: placement.activations[map.layer].addr as u64,
            arena,
        };
        // Parameters spill to the middle die for large models: approximate
        // the placement's decision by size (exact partition comes from the
        // placement stage; the simulator only cares about TSV crossings).
        let params_middle = l.param_bytes > 256 * 1024;

        match &l.op {
            Op::Conv { .. } | Op::Dense { .. } => {
                let split_n = map.m / cfg.clusters < 32; // mapper's movement rule
                let n_chunks = chunks(map.n, cfg.clusters);
                let mut out_off = 0u64;
                for (ci, prog) in programs.iter_mut().enumerate() {
                    let (m_c, n_c) = if split_n {
                        (map.m, n_chunks[ci])
                    } else {
                        (map.m_per_cluster[ci], map.n)
                    };
                    if m_c == 0 || n_c == 0 {
                        continue;
                    }
                    emit_gemm(
                        prog,
                        cfg,
                        map,
                        m_c,
                        n_c,
                        in_shape.elems(),
                        split_n,
                        params_middle,
                        lanes,
                        &bases,
                        out_off,
                        local_cap,
                    );
                    out_off += (m_c * n_c) as u64;
                }
            }
            Op::DwConv { stride } => {
                let rows = chunks(l.out_shape.h, cfg.clusters);
                let mut row0 = 0usize;
                for (ci, prog) in programs.iter_mut().enumerate() {
                    let h_c = rows[ci];
                    if h_c == 0 {
                        continue;
                    }
                    let w = l.out_shape.w;
                    let c = l.out_shape.c;
                    // input slab incl. halo at the producing stride
                    let in_rows = h_c * stride + 2;
                    let in_bytes = (in_rows * in_shape.w * in_shape.c) as u64;
                    let param_bytes = (9 * c + 4 * c) as u64;
                    let mut local = LocalArena::new(local_cap);
                    let param_slot = local.alloc(b32(param_bytes));
                    let act_slot = local.alloc(b32(in_bytes));
                    if cfg.aiu_enabled {
                        prog.instrs.push(Instr::AiuLoop { reg: 0, count: h_c as u32, stride: w as u32 });
                    }
                    prog.instrs.push(load_at(
                        map.use_dmpa,
                        param_space(false),
                        l2win(bases.param, b32(param_bytes), arena),
                        param_slot,
                        param_bytes,
                    ));
                    let in_off = (row0 * stride).saturating_sub(1) * in_shape.w * in_shape.c;
                    prog.instrs.push(load_at(
                        map.use_dmpa,
                        act_space(g, map.layer),
                        l2win(bases.input + in_off as u64, b32(in_bytes), arena),
                        act_slot,
                        in_bytes,
                    ));
                    prog.instrs.push(Instr::Sync);
                    for c0 in (0..c).step_by(lanes) {
                        let c_tile = lanes.min(c - c0);
                        if !cfg.aiu_enabled {
                            prog.instrs.push(Instr::RouteCfg { pattern: 1 });
                        }
                        prog.instrs.push(Instr::DwTile { h: h_c as u32, w: w as u32, c: c_tile as u32, stride: *stride as u8 });
                    }
                    prog.instrs.push(Instr::Sync);
                    let out_bytes = (h_c * w * c) as u64;
                    prog.instrs.push(store_at(
                        map.use_dmpa,
                        act_space(g, map.layer),
                        l2win(bases.out + (row0 * w * c) as u64, b32(out_bytes), arena),
                        0,
                        out_bytes,
                    ));
                    prog.instrs.push(Instr::Sync);
                    row0 += h_c;
                }
            }
            Op::Add => {
                let parts = chunks(l.out_shape.elems(), cfg.clusters);
                let mut off = 0u64;
                for (ci, prog) in programs.iter_mut().enumerate() {
                    let n = parts[ci];
                    if n == 0 {
                        continue;
                    }
                    let mut local = LocalArena::new(local_cap);
                    let slot = local.alloc(b32(2 * n as u64));
                    prog.instrs.push(load_at(
                        map.use_dmpa,
                        act_space(g, map.layer),
                        l2win(bases.input + off, b32(2 * n as u64), arena),
                        slot,
                        2 * n as u64,
                    ));
                    prog.instrs.push(Instr::Sync);
                    if !cfg.aiu_enabled {
                        prog.instrs.push(Instr::RouteCfg { pattern: 2 });
                    }
                    prog.instrs.push(Instr::AddTile { n: n as u32 });
                    prog.instrs.push(Instr::Sync);
                    prog.instrs.push(store_at(
                        map.use_dmpa,
                        act_space(g, map.layer),
                        l2win(bases.out + off, b32(n as u64), arena),
                        0,
                        n as u64,
                    ));
                    prog.instrs.push(Instr::Sync);
                    off += n as u64;
                }
            }
            Op::NluSigmoid => {
                let parts = chunks(l.out_shape.elems(), cfg.clusters);
                let mut off = 0u64;
                for (ci, prog) in programs.iter_mut().enumerate() {
                    let n = parts[ci];
                    if n == 0 {
                        continue;
                    }
                    let mut local = LocalArena::new(local_cap);
                    let slot = local.alloc(b32(n as u64));
                    prog.instrs.push(load_at(
                        map.use_dmpa,
                        act_space(g, map.layer),
                        l2win(bases.input + off, b32(n as u64), arena),
                        slot,
                        n as u64,
                    ));
                    prog.instrs.push(Instr::Sync);
                    prog.instrs.push(Instr::ActTile { n: n as u32, nlu: true });
                    prog.instrs.push(Instr::Sync);
                    prog.instrs.push(store_at(
                        map.use_dmpa,
                        act_space(g, map.layer),
                        l2win(bases.out + off, b32(n as u64), arena),
                        0,
                        n as u64,
                    ));
                    prog.instrs.push(Instr::Sync);
                    off += n as u64;
                }
            }
            Op::GlobalAvgPool => {
                // channels across clusters
                let parts = chunks(in_shape.c, cfg.clusters);
                let mut c0 = 0usize;
                for (ci, prog) in programs.iter_mut().enumerate() {
                    let c = parts[ci];
                    if c == 0 {
                        continue;
                    }
                    let n = in_shape.h * in_shape.w * c;
                    let mut local = LocalArena::new(local_cap);
                    let slot = local.alloc(b32(n as u64));
                    prog.instrs.push(load_at(
                        map.use_dmpa,
                        act_space(g, map.layer),
                        l2win(bases.input + (in_shape.h * in_shape.w * c0) as u64, b32(n as u64), arena),
                        slot,
                        n as u64,
                    ));
                    prog.instrs.push(Instr::Sync);
                    prog.instrs.push(Instr::PoolTile { h: in_shape.h as u32, w: in_shape.w as u32, c: c as u32 });
                    prog.instrs.push(Instr::Sync);
                    prog.instrs.push(store_at(
                        map.use_dmpa,
                        act_space(g, map.layer),
                        l2win(bases.out + c0 as u64, b32(c as u64), arena),
                        0,
                        c as u64,
                    ));
                    prog.instrs.push(Instr::Sync);
                    c0 += c;
                }
            }
            Op::Upsample2x { to_h, to_w } => {
                // pure DMPA data movement: strided read, replicated write
                let rows = chunks(*to_h, cfg.clusters);
                let mut out_off = 0u64;
                for (ci, prog) in programs.iter_mut().enumerate() {
                    let h_c = rows[ci];
                    if h_c == 0 {
                        continue;
                    }
                    let bytes_out = (h_c * to_w * l.out_shape.c) as u64;
                    let mut local = LocalArena::new(local_cap);
                    let slot = local.alloc(b32(bytes_out / 4));
                    prog.instrs.push(load_at(
                        map.use_dmpa,
                        act_space(g, map.layer),
                        l2win(bases.input + out_off / 4, b32(bytes_out / 4), arena),
                        slot,
                        bytes_out / 4,
                    ));
                    prog.instrs.push(store_at(
                        map.use_dmpa,
                        act_space(g, map.layer),
                        l2win(bases.out + out_off, b32(bytes_out), arena),
                        slot,
                        bytes_out,
                    ));
                    prog.instrs.push(Instr::Sync);
                    out_off += bytes_out;
                }
            }
        }
    }
    for prog in &mut programs {
        prog.instrs.push(Instr::Halt);
    }

    // Debug-assert verify hook: every program emitted anywhere in the test
    // suite (including randomized property graphs) must satisfy the static
    // verifier — codegen bugs fail loudly at the emission site.
    #[cfg(debug_assertions)]
    {
        let report = crate::verify::verify_programs(&programs, cfg, &crate::verify::VerifyPolicy::default());
        debug_assert!(
            report.is_clean(),
            "codegen emitted a program the verifier rejects for {}:\n{}",
            g.name,
            report.render_text()
        );
    }
    Ok(programs)
}

/// Emit one cluster's share of a GEMM layer.
#[allow(clippy::too_many_arguments)]
fn emit_gemm(
    prog: &mut Program,
    cfg: &ArchConfig,
    map: &LayerMap,
    m_c: usize,
    n_c: usize,
    in_elems: usize,
    split_n: bool,
    params_middle: bool,
    lanes: usize,
    bases: &Bases,
    out_off: u64,
    local_cap: u32,
) {
    let (bm, bk, bn) = (map.bm.min(m_c), map.bk, map.bn.min(n_c));
    let k = map.k;
    let tiles_m = m_c.div_ceil(bm);
    let tiles_n = n_c.div_ceil(bn);
    let tiles_k = k.div_ceil(bk);
    let _ = lanes;

    // activation slice for this cluster: its M rows (K-wide reads are
    // generated by the AGU from the fmap slice, charged once)
    let act_bytes = if split_n { in_elems as u64 } else { (in_elems / map.m.max(1)) as u64 * m_c as u64 };
    let act_tile = act_bytes / tiles_m as u64;

    // local layout: one streaming act slot, the bias vector, and a
    // ping-pong pair of weight-tile slots (the double buffer the hazard
    // pass checks)
    let mut local = LocalArena::new(local_cap);
    let act_slot = local.alloc(b32(act_tile));
    let bias_bytes = 4 * n_c as u64;
    let bias_slot = local.alloc(b32(bias_bytes));
    let w_slots = [local.alloc(b32((bk * bn) as u64)), local.alloc(b32((bk * bn) as u64))];
    let mut w_phase = 0usize;

    if cfg.aiu_enabled {
        // one hardware loop per level drives routing for the whole layer
        prog.instrs.push(Instr::AiuLoop { reg: 0, count: tiles_m as u32, stride: bm as u32 });
        prog.instrs.push(Instr::AiuLoop { reg: 1, count: (tiles_n * tiles_k) as u32, stride: bn as u32 });
    }
    // biases travel with the first weight tile
    prog.instrs.push(load_at(
        map.use_dmpa,
        param_space(params_middle),
        l2win(bases.param, b32(bias_bytes), bases.arena),
        bias_slot,
        bias_bytes,
    ));

    for tm in 0..tiles_m {
        let bm_eff = bm.min(m_c - tm * bm);
        // per-m-tile activation load (xfer engine; overlaps previous step)
        prog.instrs.push(load_at(
            map.use_dmpa,
            Space::L2Bottom,
            l2win(bases.input + tm as u64 * act_tile, b32(act_tile), bases.arena),
            act_slot,
            act_tile,
        ));
        for tn in 0..tiles_n {
            let bn_eff = bn.min(n_c - tn * bn);
            for tk in 0..tiles_k {
                let bk_eff = bk.min(k - tk * bk);
                // weight tile prefetch (reloaded per m-tile: output-stationary)
                let w_off = bias_bytes + ((tn * tiles_k + tk) * bk * bn) as u64;
                prog.instrs.push(load_at(
                    map.use_dmpa,
                    param_space(params_middle),
                    l2win(bases.param + w_off, b32((bk_eff * bn_eff) as u64), bases.arena),
                    w_slots[w_phase],
                    (bk_eff * bn_eff) as u64,
                ));
                w_phase ^= 1;
                if !cfg.aiu_enabled {
                    prog.instrs.push(Instr::RouteCfg { pattern: 0 });
                }
                prog.instrs.push(Instr::ConvTile {
                    m: bm_eff as u32,
                    k: bk_eff as u32,
                    n: bn_eff as u32,
                    first: tk == 0,
                    last: tk == tiles_k - 1,
                });
            }
        }
        prog.instrs.push(Instr::Sync);
    }
    prog.instrs.push(store_at(
        map.use_dmpa,
        Space::L2Bottom,
        l2win(bases.out + out_off, b32((m_c * n_c) as u64), bases.arena),
        0,
        (m_c * n_c) as u64,
    ));
    prog.instrs.push(Instr::Sync);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::mapper;
    use crate::graph::Shape;
    use crate::models;

    fn compile_programs(g: &Graph, cfg: &ArchConfig) -> Vec<Program> {
        let p = mapper::place_memory(g, cfg).unwrap();
        let maps = mapper::map_layers(g, cfg, &p).unwrap();
        emit(g, cfg, &maps, &p).unwrap()
    }

    #[test]
    fn every_cluster_halts() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let cfg = ArchConfig::j3dai();
        for p in compile_programs(&g, &cfg) {
            assert_eq!(p.instrs.last(), Some(&Instr::Halt));
        }
    }

    #[test]
    fn gemm_macs_conserved_under_tiling() {
        let g = models::paper_mbv1();
        let cfg = ArchConfig::j3dai();
        let progs = compile_programs(&g, &cfg);
        let emitted: u64 = progs.iter().map(|p| p.total_macs()).sum();
        assert_eq!(emitted, g.total_macs());
    }

    #[test]
    fn dense_layer_splits_over_n() {
        // fc of MBv1: m=1 -> split N; every cluster gets some outputs
        let g = models::paper_mbv1();
        let cfg = ArchConfig::j3dai();
        let progs = compile_programs(&g, &cfg);
        // every cluster program ends with work for the dense layer (the fc
        // ConvTile has m=1)
        for p in &progs {
            let has_m1 = p.instrs.iter().any(|i| matches!(i, Instr::ConvTile { m: 1, .. }));
            assert!(has_m1, "dense not split across clusters");
        }
    }

    #[test]
    fn route_cfg_only_without_aiu() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let on = compile_programs(&g, &ArchConfig::j3dai());
        let off_cfg = ArchConfig { aiu_enabled: false, ..ArchConfig::j3dai() };
        let off = compile_programs(&g, &off_cfg);
        let count = |ps: &[Program]| {
            ps.iter().flat_map(|p| &p.instrs).filter(|i| matches!(i, Instr::RouteCfg { .. })).count()
        };
        assert_eq!(count(&on), 0);
        assert!(count(&off) > 0);
        let aiu = |ps: &[Program]| {
            ps.iter().flat_map(|p| &p.instrs).filter(|i| matches!(i, Instr::AiuLoop { .. })).count()
        };
        assert!(aiu(&on) > 0);
        assert_eq!(aiu(&off), 0);
    }

    #[test]
    fn dma_fallback_uses_dma_ops() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let cfg = ArchConfig { dmpa_enabled: false, ..ArchConfig::j3dai() };
        let progs = compile_programs(&g, &cfg);
        let any_dmpa = progs.iter().flat_map(|p| &p.instrs).any(|i| matches!(i, Instr::DmpaLoad { .. } | Instr::DmpaStore { .. }));
        assert!(!any_dmpa);
    }

    #[test]
    fn every_layer_is_marked_on_every_cluster() {
        let g = models::paper_mbv1();
        let cfg = ArchConfig::j3dai();
        for p in compile_programs(&g, &cfg) {
            let marks: Vec<u32> = p
                .instrs
                .iter()
                .filter_map(|i| match i {
                    Instr::LayerMark { id } => Some(*id),
                    _ => None,
                })
                .collect();
            let expect: Vec<u32> = (0..g.layers.len() as u32).collect();
            assert_eq!(marks, expect);
        }
    }

    #[test]
    fn sync_separates_tile_steps() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let progs = compile_programs(&g, &ArchConfig::j3dai());
        let syncs = progs[0].instrs.iter().filter(|i| matches!(i, Instr::Sync)).count();
        assert!(syncs >= 3, "expected per-step barriers, got {syncs}");
    }

    #[test]
    fn transfers_carry_placement_addresses() {
        // at least one load must read from a nonzero L2 base (the placement
        // packs parameters bottom-up, so only the first block sits at 0)
        let g = models::paper_mbv1();
        let progs = compile_programs(&g, &ArchConfig::j3dai());
        let nonzero_src = progs.iter().flat_map(|p| &p.instrs).any(|i| {
            matches!(i, Instr::DmpaLoad { src_addr, .. } | Instr::DmaLoad { src_addr, .. } if *src_addr != 0)
        });
        assert!(nonzero_src, "loads never reference placement addresses");
        let nonzero_dst = progs.iter().flat_map(|p| &p.instrs).any(|i| {
            matches!(i, Instr::DmpaStore { dst_addr, .. } | Instr::DmaStore { dst_addr, .. } if *dst_addr != 0)
        });
        assert!(nonzero_dst, "stores never reference placement addresses");
    }

    #[test]
    fn emitted_programs_verify_clean() {
        use crate::verify::{verify_programs, VerifyPolicy};
        let cfg = ArchConfig::j3dai();
        for g in [models::paper_mbv1(), models::paper_mbv2(), models::paper_seg()] {
            let progs = compile_programs(&g, &cfg);
            let report = verify_programs(&progs, &cfg, &VerifyPolicy::default());
            assert!(report.is_clean(), "{}:\n{}", g.name, report.render_text());
        }
    }

    #[test]
    fn local_arena_spill_windows_run_past_the_top() {
        let mut a = LocalArena::new(1024);
        let x = a.alloc(512);
        let y = a.alloc(512);
        assert_ne!(x, y);
        // next allocation cannot fit: base stays in range, extent spills
        let z = a.alloc(64);
        assert!(z < 1024);
        assert!(z as u64 + 64 >= 1024);
    }
}
