//! Codegen — emit per-cluster macro-op programs from the layer maps.
//!
//! Loop structure per GEMM layer (output-stationary, the paper's
//! "computing process" with masked parameter loads):
//!
//! ```text
//! for tm in M-tiles:              # rows of this cluster's slice
//!     dmpa.load act(tm)           # xfer engine — overlaps previous tile
//!     for tn in N-tiles:
//!         for tk in K-tiles:
//!             dmpa.load w(tn,tk)  # prefetched ahead of the MACs
//!             conv.tile bm x bk x bn
//!     sync                        # step boundary: max(xfer, compute)
//! dmpa.store out
//! ```
//!
//! With the AIU enabled, one `aiu.loop` instruction per loop level replaces
//! the per-tile routing configuration; with it disabled a `route.cfg` is
//! emitted before every tile — reproducing the §III-B2 program-footprint
//! and ops/cycle claims.

use crate::config::ArchConfig;
use crate::graph::{Graph, Op, INPUT};
use crate::isa::{Instr, Program, Space};

use super::mapper::LayerMap;

/// Address of a layer's L2 activation buffer — codegen uses logical
/// addresses (the placement stage owns physical ones; the simulator only
/// needs spaces + sizes).
fn act_space(_g: &Graph, _li: usize) -> Space {
    Space::L2Bottom
}

/// Which L2 partition a layer's parameters were placed in: big late-model
/// tensors spill to the middle die. Codegen receives this from placement
/// through the layer map in a full implementation; here parameters beyond
/// the bottom partition budget were marked by the mapper.
fn param_space(middle: bool) -> Space {
    if middle { Space::L2Middle } else { Space::L2Bottom }
}

/// Emit the load instruction for the selected transfer engine.
fn load(use_dmpa: bool, src: Space, bytes: u64) -> Instr {
    let bytes = bytes.min(u32::MAX as u64) as u32;
    if use_dmpa {
        Instr::DmpaLoad { src, src_addr: 0, dst_addr: 0, bytes }
    } else {
        Instr::DmaLoad { src, src_addr: 0, dst_addr: 0, bytes }
    }
}

fn store(use_dmpa: bool, dst: Space, bytes: u64) -> Instr {
    let bytes = bytes.min(u32::MAX as u64) as u32;
    if use_dmpa {
        Instr::DmpaStore { dst, dst_addr: 0, src_addr: 0, bytes }
    } else {
        Instr::DmaStore { dst, dst_addr: 0, src_addr: 0, bytes }
    }
}

/// Split `n` into `parts` contiguous chunks (first chunks get the remainder).
fn chunks(n: usize, parts: usize) -> Vec<usize> {
    super::mapper::split_rows(n, parts)
}

/// Emit all cluster programs for the graph.
pub fn emit(g: &Graph, cfg: &ArchConfig, maps: &[LayerMap]) -> crate::Result<Vec<Program>> {
    let mut programs: Vec<Program> = (0..cfg.clusters).map(|_| Program::default()).collect();
    let lanes = cfg.cluster_macs_per_cycle() as usize;

    for map in maps {
        let l = &g.layers[map.layer];
        // telemetry marker: attribute the following instructions to this
        // layer in traced simulation (free on both engines)
        for prog in programs.iter_mut() {
            prog.instrs.push(Instr::LayerMark { id: map.layer as u32 });
        }
        let in_shape = if l.inputs[0] == INPUT { g.input } else { g.layers[l.inputs[0]].out_shape };
        // Parameters spill to the middle die for large models: approximate
        // the placement's decision by size (exact partition comes from the
        // placement stage; the simulator only cares about TSV crossings).
        let params_middle = l.param_bytes > 256 * 1024;

        match &l.op {
            Op::Conv { .. } | Op::Dense { .. } => {
                let split_n = map.m / cfg.clusters < 32; // mapper's movement rule
                let n_chunks = chunks(map.n, cfg.clusters);
                for (ci, prog) in programs.iter_mut().enumerate() {
                    let (m_c, n_c) = if split_n {
                        (map.m, n_chunks[ci])
                    } else {
                        (map.m_per_cluster[ci], map.n)
                    };
                    if m_c == 0 || n_c == 0 {
                        continue;
                    }
                    emit_gemm(prog, cfg, map, m_c, n_c, in_shape.elems(), split_n, params_middle, lanes);
                }
            }
            Op::DwConv { stride } => {
                let rows = chunks(l.out_shape.h, cfg.clusters);
                for (ci, prog) in programs.iter_mut().enumerate() {
                    let h_c = rows[ci];
                    if h_c == 0 {
                        continue;
                    }
                    let w = l.out_shape.w;
                    let c = l.out_shape.c;
                    // input slab incl. halo at the producing stride
                    let in_rows = h_c * stride + 2;
                    let in_bytes = (in_rows * in_shape.w * in_shape.c) as u64;
                    if cfg.aiu_enabled {
                        prog.instrs.push(Instr::AiuLoop { reg: 0, count: h_c as u32, stride: w as u32 });
                    }
                    prog.instrs.push(load(map.use_dmpa, param_space(false), (9 * c + 4 * c) as u64));
                    prog.instrs.push(load(map.use_dmpa, act_space(g, map.layer), in_bytes));
                    prog.instrs.push(Instr::Sync);
                    for c0 in (0..c).step_by(lanes) {
                        let c_tile = lanes.min(c - c0);
                        if !cfg.aiu_enabled {
                            prog.instrs.push(Instr::RouteCfg { pattern: 1 });
                        }
                        prog.instrs.push(Instr::DwTile { h: h_c as u32, w: w as u32, c: c_tile as u32, stride: *stride as u8 });
                    }
                    prog.instrs.push(Instr::Sync);
                    prog.instrs.push(store(map.use_dmpa, act_space(g, map.layer), (h_c * w * c) as u64));
                    prog.instrs.push(Instr::Sync);
                }
            }
            Op::Add => {
                let parts = chunks(l.out_shape.elems(), cfg.clusters);
                for (ci, prog) in programs.iter_mut().enumerate() {
                    let n = parts[ci];
                    if n == 0 {
                        continue;
                    }
                    prog.instrs.push(load(map.use_dmpa, act_space(g, map.layer), 2 * n as u64));
                    prog.instrs.push(Instr::Sync);
                    if !cfg.aiu_enabled {
                        prog.instrs.push(Instr::RouteCfg { pattern: 2 });
                    }
                    prog.instrs.push(Instr::AddTile { n: n as u32 });
                    prog.instrs.push(Instr::Sync);
                    prog.instrs.push(store(map.use_dmpa, act_space(g, map.layer), n as u64));
                    prog.instrs.push(Instr::Sync);
                }
            }
            Op::NluSigmoid => {
                let parts = chunks(l.out_shape.elems(), cfg.clusters);
                for (ci, prog) in programs.iter_mut().enumerate() {
                    let n = parts[ci];
                    if n == 0 {
                        continue;
                    }
                    prog.instrs.push(load(map.use_dmpa, act_space(g, map.layer), n as u64));
                    prog.instrs.push(Instr::Sync);
                    prog.instrs.push(Instr::ActTile { n: n as u32, nlu: true });
                    prog.instrs.push(Instr::Sync);
                    prog.instrs.push(store(map.use_dmpa, act_space(g, map.layer), n as u64));
                    prog.instrs.push(Instr::Sync);
                }
            }
            Op::GlobalAvgPool => {
                // channels across clusters
                let parts = chunks(in_shape.c, cfg.clusters);
                for (ci, prog) in programs.iter_mut().enumerate() {
                    let c = parts[ci];
                    if c == 0 {
                        continue;
                    }
                    let n = in_shape.h * in_shape.w * c;
                    prog.instrs.push(load(map.use_dmpa, act_space(g, map.layer), n as u64));
                    prog.instrs.push(Instr::Sync);
                    prog.instrs.push(Instr::PoolTile { h: in_shape.h as u32, w: in_shape.w as u32, c: c as u32 });
                    prog.instrs.push(Instr::Sync);
                    prog.instrs.push(store(map.use_dmpa, act_space(g, map.layer), c as u64));
                    prog.instrs.push(Instr::Sync);
                }
            }
            Op::Upsample2x { to_h, to_w } => {
                // pure DMPA data movement: strided read, replicated write
                let rows = chunks(*to_h, cfg.clusters);
                for (ci, prog) in programs.iter_mut().enumerate() {
                    let h_c = rows[ci];
                    if h_c == 0 {
                        continue;
                    }
                    let bytes_out = (h_c * to_w * l.out_shape.c) as u64;
                    prog.instrs.push(load(map.use_dmpa, act_space(g, map.layer), bytes_out / 4));
                    prog.instrs.push(store(map.use_dmpa, act_space(g, map.layer), bytes_out));
                    prog.instrs.push(Instr::Sync);
                }
            }
        }
    }
    for prog in &mut programs {
        prog.instrs.push(Instr::Halt);
    }
    Ok(programs)
}

/// Emit one cluster's share of a GEMM layer.
#[allow(clippy::too_many_arguments)]
fn emit_gemm(
    prog: &mut Program,
    cfg: &ArchConfig,
    map: &LayerMap,
    m_c: usize,
    n_c: usize,
    in_elems: usize,
    split_n: bool,
    params_middle: bool,
    lanes: usize,
) {
    let (bm, bk, bn) = (map.bm.min(m_c), map.bk, map.bn.min(n_c));
    let k = map.k;
    let tiles_m = m_c.div_ceil(bm);
    let tiles_n = n_c.div_ceil(bn);
    let tiles_k = k.div_ceil(bk);
    let _ = lanes;

    // activation slice for this cluster: its M rows (K-wide reads are
    // generated by the AGU from the fmap slice, charged once)
    let act_bytes = if split_n { in_elems as u64 } else { (in_elems / map.m.max(1)) as u64 * m_c as u64 };

    if cfg.aiu_enabled {
        // one hardware loop per level drives routing for the whole layer
        prog.instrs.push(Instr::AiuLoop { reg: 0, count: tiles_m as u32, stride: bm as u32 });
        prog.instrs.push(Instr::AiuLoop { reg: 1, count: (tiles_n * tiles_k) as u32, stride: bn as u32 });
    }
    // biases travel with the first weight tile
    let bias_bytes = 4 * n_c as u64;
    prog.instrs.push(load(map.use_dmpa, param_space(params_middle), bias_bytes));

    for tm in 0..tiles_m {
        let bm_eff = bm.min(m_c - tm * bm);
        // per-m-tile activation load (xfer engine; overlaps previous step)
        prog.instrs.push(load(map.use_dmpa, Space::L2Bottom, act_bytes / tiles_m as u64));
        for tn in 0..tiles_n {
            let bn_eff = bn.min(n_c - tn * bn);
            for tk in 0..tiles_k {
                let bk_eff = bk.min(k - tk * bk);
                // weight tile prefetch (reloaded per m-tile: output-stationary)
                prog.instrs.push(load(
                    map.use_dmpa,
                    param_space(params_middle),
                    (bk_eff * bn_eff) as u64,
                ));
                if !cfg.aiu_enabled {
                    prog.instrs.push(Instr::RouteCfg { pattern: 0 });
                }
                prog.instrs.push(Instr::ConvTile {
                    m: bm_eff as u32,
                    k: bk_eff as u32,
                    n: bn_eff as u32,
                    first: tk == 0,
                    last: tk == tiles_k - 1,
                });
            }
        }
        prog.instrs.push(Instr::Sync);
    }
    prog.instrs.push(store(map.use_dmpa, Space::L2Bottom, (m_c * n_c) as u64));
    prog.instrs.push(Instr::Sync);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::mapper;
    use crate::graph::Shape;
    use crate::models;

    fn compile_programs(g: &Graph, cfg: &ArchConfig) -> Vec<Program> {
        let p = mapper::place_memory(g, cfg).unwrap();
        let maps = mapper::map_layers(g, cfg, &p).unwrap();
        emit(g, cfg, &maps).unwrap()
    }

    #[test]
    fn every_cluster_halts() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let cfg = ArchConfig::j3dai();
        for p in compile_programs(&g, &cfg) {
            assert_eq!(p.instrs.last(), Some(&Instr::Halt));
        }
    }

    #[test]
    fn gemm_macs_conserved_under_tiling() {
        let g = models::paper_mbv1();
        let cfg = ArchConfig::j3dai();
        let progs = compile_programs(&g, &cfg);
        let emitted: u64 = progs.iter().map(|p| p.total_macs()).sum();
        assert_eq!(emitted, g.total_macs());
    }

    #[test]
    fn dense_layer_splits_over_n() {
        // fc of MBv1: m=1 -> split N; every cluster gets some outputs
        let g = models::paper_mbv1();
        let cfg = ArchConfig::j3dai();
        let progs = compile_programs(&g, &cfg);
        // every cluster program ends with work for the dense layer (the fc
        // ConvTile has m=1)
        for p in &progs {
            let has_m1 = p.instrs.iter().any(|i| matches!(i, Instr::ConvTile { m: 1, .. }));
            assert!(has_m1, "dense not split across clusters");
        }
    }

    #[test]
    fn route_cfg_only_without_aiu() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let on = compile_programs(&g, &ArchConfig::j3dai());
        let off_cfg = ArchConfig { aiu_enabled: false, ..ArchConfig::j3dai() };
        let off = compile_programs(&g, &off_cfg);
        let count = |ps: &[Program]| {
            ps.iter().flat_map(|p| &p.instrs).filter(|i| matches!(i, Instr::RouteCfg { .. })).count()
        };
        assert_eq!(count(&on), 0);
        assert!(count(&off) > 0);
        let aiu = |ps: &[Program]| {
            ps.iter().flat_map(|p| &p.instrs).filter(|i| matches!(i, Instr::AiuLoop { .. })).count()
        };
        assert!(aiu(&on) > 0);
        assert_eq!(aiu(&off), 0);
    }

    #[test]
    fn dma_fallback_uses_dma_ops() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let cfg = ArchConfig { dmpa_enabled: false, ..ArchConfig::j3dai() };
        let progs = compile_programs(&g, &cfg);
        let any_dmpa = progs.iter().flat_map(|p| &p.instrs).any(|i| matches!(i, Instr::DmpaLoad { .. } | Instr::DmpaStore { .. }));
        assert!(!any_dmpa);
    }

    #[test]
    fn every_layer_is_marked_on_every_cluster() {
        let g = models::paper_mbv1();
        let cfg = ArchConfig::j3dai();
        for p in compile_programs(&g, &cfg) {
            let marks: Vec<u32> = p
                .instrs
                .iter()
                .filter_map(|i| match i {
                    Instr::LayerMark { id } => Some(*id),
                    _ => None,
                })
                .collect();
            let expect: Vec<u32> = (0..g.layers.len() as u32).collect();
            assert_eq!(marks, expect);
        }
    }

    #[test]
    fn sync_separates_tile_steps() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let progs = compile_programs(&g, &ArchConfig::j3dai());
        let syncs = progs[0].instrs.iter().filter(|i| matches!(i, Instr::Sync)).count();
        assert!(syncs >= 3, "expected per-step barriers, got {syncs}");
    }
}
