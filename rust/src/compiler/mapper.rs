//! Mapping solver — "a dedicated solver explores multiple mapping solutions
//! to find the optimal data memory placement ... minimizes the need for
//! data movement during computation to achieve the best operation per cycle
//! rate. It checks if the data fit in memory and generates metrics like
//! computing resource usage." (paper §III-C2)
//!
//! Two stages:
//!  - [`place_memory`]: liveness-based L2 placement of parameters and
//!    activations, filling the bottom-die 3 MB first (DMPA-adjacent) and
//!    spilling to the middle-die 2 MB partition (TSV-crossing).
//!  - [`map_layers`]: per-layer tiling search — candidate (bm, bk, bn)
//!    GEMM tiles / depthwise slabs are enumerated, rejected if the working
//!    set exceeds the per-cluster NCB SRAM, and scored by
//!    `compute_cycles + transfer_cycles_not_overlappable`.

use crate::config::ArchConfig;
use crate::graph::{Graph, Op, INPUT};

use super::L2Alloc;

/// Result of L2 memory placement.
#[derive(Debug, Clone)]
pub struct Placement {
    /// Per-layer parameter allocation (None for parameterless ops).
    pub params: Vec<Option<L2Alloc>>,
    /// Per-layer output-activation allocation.
    pub activations: Vec<L2Alloc>,
    /// Allocation for the network input.
    pub input: L2Alloc,
    pub param_bytes: u64,
    pub peak_activation_bytes: u64,
}

/// How one layer is tiled and distributed (the "computing process").
#[derive(Debug, Clone)]
pub struct LayerMap {
    pub layer: usize,
    pub name: String,
    /// GEMM view (m, k, n) of the layer (dw/elementwise use their own view).
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Chosen tile sizes.
    pub bm: usize,
    pub bk: usize,
    pub bn: usize,
    /// Rows of M assigned to each cluster (length = clusters).
    pub m_per_cluster: Vec<usize>,
    /// Use DMPA (true) or fall back to DMA (false) for tensor transfers.
    pub use_dmpa: bool,
    /// Estimated PE utilization within compute tiles (0..=1).
    pub pe_utilization: f64,
    /// Working-set bytes per cluster at the chosen tiling.
    pub working_set_bytes: usize,
}

/// Liveness-based L2 placement (first-fit over a two-partition arena).
pub fn place_memory(g: &Graph, cfg: &ArchConfig) -> crate::Result<Placement> {
    let bottom = cfg.l2_bottom_bytes as u32;
    let total = cfg.l2_bytes() as u32;
    // Activations may also live in the flattened NCB SRAM (the paper's
    // "no specific memory bank is dedicated to filter parameters or feature
    // maps" §III-B3): half the 1.5 MB local budget extends the arena, the
    // other half is the tiles' working space.
    let cap = cfg.l2_arena_bytes() as u32;

    // Parameters are resident for the whole run: pack them first, bottom up.
    let mut cursor: u32 = 0;
    let mut params = Vec::with_capacity(g.layers.len());
    for l in &g.layers {
        if l.param_bytes == 0 {
            params.push(None);
            continue;
        }
        let bytes = l.param_bytes as u32;
        anyhow::ensure!(cursor + bytes <= total, "parameters overflow L2: {} needs {}", g.name, cursor + bytes);
        params.push(Some(L2Alloc { addr: cursor, bytes, middle: cursor >= bottom }));
        cursor += bytes;
    }
    let param_bytes = cursor as u64;

    // Activations: double-buffer arena above the parameters. A layer output
    // is live from its production until its last consumer; we use a simple
    // two-slot rotation extended for residual edges (max live set of the
    // paper's models is 3 tensors).
    let mut last_use = vec![0usize; g.layers.len()];
    for (i, l) in g.layers.iter().enumerate() {
        for &j in &l.inputs {
            if j != INPUT {
                last_use[j] = i;
            }
        }
    }
    let arena_base = cursor;
    let mut live: Vec<(usize, u32, u32)> = Vec::new(); // (layer, addr, bytes)
    let mut activations: Vec<L2Alloc> = Vec::with_capacity(g.layers.len());
    let input_bytes = g.input.elems() as u32;
    anyhow::ensure!(arena_base + input_bytes <= cap, "input overflows L2");
    let input_alloc = L2Alloc { addr: arena_base, bytes: input_bytes, middle: arena_base >= bottom };
    let mut peak = arena_base as u64 + input_bytes as u64;
    live.push((INPUT, arena_base, input_bytes));

    for (i, l) in g.layers.iter().enumerate() {
        // free tensors whose last use is before i (input stays resident)
        live.retain(|&(prod, _, _)| prod == INPUT || last_use[prod] >= i);
        let bytes = l.out_shape.elems() as u32;
        // first-fit above arena_base avoiding live ranges
        let mut addr = arena_base + input_bytes; // keep input resident (re-runs)
        let mut placed = false;
        let mut guard = 0;
        while !placed {
            guard += 1;
            anyhow::ensure!(guard < 10_000, "placement loop stuck");
            let conflict = live.iter().find(|&&(_, a, b)| addr < a + b && a < addr + bytes);
            match conflict {
                Some(&(_, a, b)) => addr = a + b,
                None => placed = true,
            }
        }
        anyhow::ensure!(addr + bytes <= cap, "activations overflow L2+local at layer {}", l.name);
        activations.push(L2Alloc { addr, bytes, middle: addr >= bottom });
        live.push((i, addr, bytes));
        peak = peak.max(addr as u64 + bytes as u64);
    }

    Ok(Placement {
        params,
        activations,
        input: input_alloc,
        param_bytes,
        peak_activation_bytes: peak - arena_base as u64,
    })
}

/// Split `m` rows across `clusters` as evenly as possible.
pub fn split_rows(m: usize, clusters: usize) -> Vec<usize> {
    let base = m / clusters;
    let rem = m % clusters;
    (0..clusters).map(|i| base + usize::from(i < rem)).collect()
}

/// The GEMM view (M, K, N) of a compute layer.
pub fn gemm_view(g: &Graph, li: usize) -> Option<(usize, usize, usize)> {
    let l = &g.layers[li];
    let in_shape = if l.inputs[0] == INPUT { g.input } else { g.layers[l.inputs[0]].out_shape };
    match &l.op {
        Op::Conv { kh, kw, cout, .. } => {
            let m = l.out_shape.h * l.out_shape.w;
            Some((m, kh * kw * in_shape.c, *cout))
        }
        Op::Dense { out } => Some((1, in_shape.elems(), *out)),
        _ => None,
    }
}

/// Tile-size search for one GEMM layer. Returns (bm, bk, bn, utilization,
/// working set). Mirrors the paper's solver: enumerate, check fit, score.
fn search_gemm_tiles(cfg: &ArchConfig, m_c: usize, k: usize, n: usize) -> (usize, usize, usize, f64, usize) {
    let lanes = cfg.cluster_macs_per_cycle() as usize;
    let budget = cfg.cluster_local_bytes(); // per-cluster SRAM
    let mut best: Option<(u64, usize, usize, usize, usize)> = None; // (cost, bm,bk,bn, ws)
    for &bm in &[32usize, 64, 128, 256, 512] {
        let bm = bm.min(m_c.max(1));
        for &bk in &[64usize, 128, 256, 512, 1024] {
            let bk = bk.min(k);
            for &bn in &[16usize, 32, 64, 128] {
                let bn = bn.min(n);
                // double-buffered working set: 2x act tile + 2x weight tile
                // + i32 accumulators + u8 outputs
                let ws = 2 * bm * bk + 2 * bk * bn + 4 * bm * bn + bm * bn;
                if ws > budget {
                    continue;
                }
                let tiles_m = m_c.div_ceil(bm);
                let tiles_k = k.div_ceil(bk);
                let tiles_n = n.div_ceil(bn);
                // compute cycles: each tile streams bk MACs/lane-slot
                let slot = (bm * bn).div_ceil(lanes) as u64;
                let compute = slot * bk as u64 * (tiles_m * tiles_k * tiles_n) as u64;
                // transfers that cannot overlap: first weight tile + act in
                let xfer_bytes = (bk * bn) as u64 + (bm * bk) as u64;
                let xfer = cfg.dmpa_cycles(xfer_bytes);
                // per-tile controller overhead
                let overhead = (tiles_m * tiles_k * tiles_n) as u64 * cfg.op_setup_cycles;
                let cost = compute + xfer + overhead;
                if best.map_or(true, |(c, ..)| cost < c) {
                    best = Some((cost, bm, bk, bn, ws));
                }
            }
        }
    }
    let (_, bm, bk, bn, ws) = best.expect("no feasible tiling — SRAM too small");
    let util = {
        // utilization inside one tile slot
        let used = (bm * bn) as f64;
        let slots = (bm * bn).div_ceil(lanes) as f64 * lanes as f64;
        used / slots
    };
    (bm, bk, bn, util, ws)
}

/// Map every layer of the graph.
pub fn map_layers(g: &Graph, cfg: &ArchConfig, _placement: &Placement) -> crate::Result<Vec<LayerMap>> {
    let lanes = cfg.cluster_macs_per_cycle() as usize;
    let mut maps = Vec::with_capacity(g.layers.len());
    for (li, l) in g.layers.iter().enumerate() {
        let map = match &l.op {
            Op::Conv { .. } | Op::Dense { .. } => {
                let (m, k, n) = gemm_view(g, li).unwrap();
                let m_per_cluster = split_rows(m, cfg.clusters);
                let m_c = *m_per_cluster.iter().max().unwrap();
                let (bm, bk, bn, util, ws) = search_gemm_tiles(cfg, m_c.max(1), k, n);
                LayerMap {
                    layer: li,
                    name: l.name.clone(),
                    m,
                    k,
                    n,
                    bm,
                    bk,
                    bn,
                    m_per_cluster,
                    use_dmpa: cfg.dmpa_enabled,
                    pe_utilization: util,
                    working_set_bytes: ws,
                }
            }
            Op::DwConv { .. } => {
                // spatial rows across clusters, channels across SIMD lanes
                let rows = l.out_shape.h;
                let m_per_cluster = split_rows(rows, cfg.clusters);
                let c = l.out_shape.c;
                let util = c as f64 / (c.div_ceil(lanes) * lanes) as f64;
                LayerMap {
                    layer: li,
                    name: l.name.clone(),
                    m: rows,
                    k: 9,
                    n: c,
                    bm: m_per_cluster[0].max(1),
                    bk: 9,
                    bn: c.min(lanes),
                    m_per_cluster,
                    use_dmpa: cfg.dmpa_enabled,
                    pe_utilization: util,
                    working_set_bytes: (l.out_shape.w + 2) * 3 * c.min(lanes),
                }
            }
            Op::Add | Op::NluSigmoid | Op::GlobalAvgPool | Op::Upsample2x { .. } => {
                let n = l.out_shape.elems();
                let m_per_cluster = split_rows(n, cfg.clusters);
                LayerMap {
                    layer: li,
                    name: l.name.clone(),
                    m: n,
                    k: 1,
                    n: 1,
                    bm: m_per_cluster[0].max(1),
                    bk: 1,
                    bn: 1,
                    m_per_cluster,
                    use_dmpa: cfg.dmpa_enabled,
                    pe_utilization: 1.0,
                    working_set_bytes: 0,
                }
            }
        };
        anyhow::ensure!(
            map.working_set_bytes <= cfg.cluster_local_bytes(),
            "layer {} working set {} exceeds cluster SRAM",
            l.name,
            map.working_set_bytes
        );
        maps.push(map);
    }
    Ok(maps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Shape;
    use crate::models;

    #[test]
    fn split_rows_is_fair_and_total() {
        assert_eq!(split_rows(10, 3), vec![4, 3, 3]);
        assert_eq!(split_rows(6, 6), vec![1; 6]);
        assert_eq!(split_rows(2, 6), vec![1, 1, 0, 0, 0, 0]);
        for (m, c) in [(100, 6), (1, 6), (768, 5)] {
            assert_eq!(split_rows(m, c).iter().sum::<usize>(), m);
        }
    }

    #[test]
    fn placement_fits_paper_models() {
        let cfg = ArchConfig::j3dai();
        for g in [models::paper_mbv1(), models::paper_mbv2(), models::paper_seg()] {
            let p = place_memory(&g, &cfg).unwrap();
            assert!(p.param_bytes > 0);
            assert!(p.param_bytes + p.peak_activation_bytes <= (cfg.l2_bytes() + cfg.local_sram_bytes() / 2) as u64);
            assert_eq!(p.activations.len(), g.layers.len());
        }
    }

    #[test]
    fn placement_spills_to_middle_partition() {
        // MBv1 alpha=1 has ~4.2 MB of parameters: some must land on the
        // middle die (addr >= 3 MB) — exercising the TSV path.
        let cfg = ArchConfig::j3dai();
        let g = models::paper_mbv1();
        let p = place_memory(&g, &cfg).unwrap();
        assert!(p.params.iter().flatten().any(|a| a.middle));
    }

    #[test]
    fn residual_liveness_no_overlap() {
        // In MBv2, the residual input must stay allocated across the block;
        // verify no two simultaneously-live tensors share addresses.
        let cfg = ArchConfig::j3dai();
        let g = models::paper_mbv2();
        let p = place_memory(&g, &cfg).unwrap();
        let mut last_use = vec![0usize; g.layers.len()];
        for (i, l) in g.layers.iter().enumerate() {
            for &j in &l.inputs {
                if j != INPUT {
                    last_use[j] = i;
                }
            }
        }
        for (i, l) in g.layers.iter().enumerate() {
            for &j in &l.inputs {
                if j == INPUT {
                    continue;
                }
                // producer j's buffer must not be overwritten by any layer
                // between j and i that is also live at i... simplified:
                let a = &p.activations[j];
                for (k2, ak) in p.activations.iter().enumerate() {
                    if k2 > j && k2 < i && last_use[j] >= k2 {
                        let overlap = a.addr < ak.addr + ak.bytes && ak.addr < a.addr + a.bytes;
                        assert!(!overlap, "layer {} clobbers live tensor {} ({})", g.layers[k2].name, g.layers[j].name, l.name);
                    }
                }
            }
        }
    }

    #[test]
    fn gemm_tiles_fit_sram() {
        let cfg = ArchConfig::j3dai();
        let g = models::paper_mbv1();
        let p = place_memory(&g, &cfg).unwrap();
        let maps = map_layers(&g, &cfg, &p).unwrap();
        let budget = cfg.ncbs_per_cluster * cfg.ncb_sram_bytes;
        for m in &maps {
            assert!(m.working_set_bytes <= budget, "{}", m.name);
            assert!(m.pe_utilization > 0.0 && m.pe_utilization <= 1.0);
        }
    }

    #[test]
    fn pointwise_layers_get_high_utilization() {
        let cfg = ArchConfig::j3dai();
        let g = models::paper_mbv1();
        let p = place_memory(&g, &cfg).unwrap();
        let maps = map_layers(&g, &cfg, &p).unwrap();
        // pw13 at 8x6x1024: m=48 per cluster -> high util expected
        let pw = maps.iter().find(|m| m.name.ends_with("/pw1")).unwrap();
        assert!(pw.pe_utilization > 0.9, "pw1 util={}", pw.pe_utilization);
    }

    #[test]
    fn tiny_input_still_maps() {
        let cfg = ArchConfig::j3dai();
        let g = models::tinycnn(Shape::new(8, 8, 3), 4);
        let p = place_memory(&g, &cfg).unwrap();
        let maps = map_layers(&g, &cfg, &p).unwrap();
        assert_eq!(maps.len(), g.layers.len());
    }
}
