//! The Aidge-export analog (paper Fig. 4): map a quantized [`Graph`] onto
//! the accelerator and emit per-cluster [`Program`]s plus a host program.
//!
//! Pipeline stages, mirroring §III-C2:
//!  1. **Mapping solver** ([`mapper`]) — explores tile-size candidates per
//!     layer, checks the NCB SRAM budget, scores data movement + PE
//!     utilization, picks the best placement and the DMPA/DMA transfer
//!     engine per tensor.
//!  2. **Scheduling solver** ([`scheduler`]) — arranges transfers to mask
//!     parameter loading behind computation (double buffering) and inserts
//!     the synchronization barriers the engines need.
//!  3. **Codegen** ([`codegen`]) — emits the macro-op programs (with AIU
//!     loop setup, or explicit RouteCfg instructions when the AIU is
//!     disabled) and the host descriptor program.

pub mod codegen;
pub mod mapper;
pub mod scheduler;

use crate::config::ArchConfig;
use crate::graph::Graph;
use crate::isa::Program;
use crate::telemetry::{Telemetry, COMPILER_PID, PASS_US_BUCKETS};

/// Where a tensor lives in L2 (the memory-placement decision).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct L2Alloc {
    /// Offset in the unified L2 address space.
    pub addr: u32,
    pub bytes: u32,
    /// True if placed in the middle-die partition (crosses TSVs).
    pub middle: bool,
}

/// Compiled artifact: one program per cluster + metadata.
#[derive(Debug, Clone)]
pub struct Compiled {
    pub model: String,
    /// One macro-op program per cluster.
    pub cluster_programs: Vec<Program>,
    /// Host-side per-layer descriptor schedule (layer name, sync cost).
    pub host_steps: Vec<HostStep>,
    /// Mapping report (per layer) for the compile_report example / tests.
    pub layer_maps: Vec<mapper::LayerMap>,
    /// Parameter bytes placed in L2 (by the memory-placement stage).
    pub param_bytes: u64,
    /// Peak activation bytes resident in L2.
    pub peak_activation_bytes: u64,
}

/// One host-program step (descriptor writes + interrupt wait per layer).
#[derive(Debug, Clone)]
pub struct HostStep {
    pub layer: String,
    /// Host cycles spent writing descriptors / polling sync registers.
    pub host_cycles: u64,
}

impl Compiled {
    /// Total encoded program size across clusters (the AIU footprint claim).
    pub fn program_bytes(&self) -> usize {
        self.cluster_programs.iter().map(|p| p.size_bytes()).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.cluster_programs.iter().map(|p| p.total_macs()).sum()
    }
}

/// Compile a graph for an architecture — the full Fig. 4 flow.
pub fn compile(g: &Graph, cfg: &ArchConfig) -> crate::Result<Compiled> {
    compile_traced(g, cfg, None)
}

/// Run one compiler pass under an optional telemetry domain: a wall-time
/// span on pid [`COMPILER_PID`] plus a `j3dai_compile_pass_us` histogram
/// observation.
fn pass<T>(
    tel: Option<&Telemetry>,
    name: &'static str,
    f: impl FnOnce() -> crate::Result<T>,
) -> crate::Result<T> {
    let Some(t) = tel else { return f() };
    let t0 = t.now_us();
    let r = t.wall_span(COMPILER_PID, 0, name, "compiler", f);
    t.registry
        .histogram_with(
            "j3dai_compile_pass_us",
            &[("pass", name)],
            "Compiler pass wall time (us)",
            PASS_US_BUCKETS,
        )
        .observe(t.now_us() - t0);
    r
}

/// [`compile`] with per-pass observability: when `tel` is given, each
/// pipeline stage is recorded as a wall-time span (pid [`COMPILER_PID`])
/// and observed into the `j3dai_compile_pass_us` histogram.
pub fn compile_traced(g: &Graph, cfg: &ArchConfig, tel: Option<&Telemetry>) -> crate::Result<Compiled> {
    g.validate()?;
    cfg.validate()?;
    if let Some(t) = tel {
        t.name_process(COMPILER_PID, "compiler");
        t.name_thread(COMPILER_PID, 0, &format!("passes:{}", g.name));
    }
    let placement = pass(tel, "place_memory", || mapper::place_memory(g, cfg))?;
    let maps = pass(tel, "map_layers", || mapper::map_layers(g, cfg, &placement))?;
    let programs = pass(tel, "codegen", || codegen::emit(g, cfg, &maps, &placement))?;
    let host_steps = pass(tel, "host_schedule", || Ok(scheduler::host_schedule(g, cfg)))?;
    // MAC conservation: the emitted programs must perform exactly the
    // graph's MACs (the mapper may not drop or duplicate work).
    let emitted: u64 = programs.iter().map(|p| p.total_macs()).sum();
    anyhow::ensure!(
        emitted == g.total_macs(),
        "MAC mismatch: graph={} emitted={}",
        g.total_macs(),
        emitted
    );
    Ok(Compiled {
        model: g.name.clone(),
        cluster_programs: programs,
        host_steps,
        param_bytes: placement.param_bytes,
        peak_activation_bytes: placement.peak_activation_bytes,
        layer_maps: maps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Shape;
    use crate::models;

    #[test]
    fn compile_tinycnn() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let cfg = ArchConfig::j3dai();
        let c = compile(&g, &cfg).unwrap();
        assert_eq!(c.cluster_programs.len(), 6);
        assert_eq!(c.total_macs(), g.total_macs());
        assert!(c.program_bytes() > 0);
        assert_eq!(c.host_steps.len(), g.layers.len());
    }

    #[test]
    fn compile_all_paper_models() {
        let cfg = ArchConfig::j3dai();
        for g in [models::paper_mbv1(), models::paper_mbv2(), models::paper_seg()] {
            let c = compile(&g, &cfg).unwrap();
            assert_eq!(c.total_macs(), g.total_macs(), "{}", g.name);
            // parameters must fit the 5 MB L2 alongside peak activations
            let cap = cfg.l2_arena_bytes() as u64;
            assert!(c.param_bytes + c.peak_activation_bytes <= cap, "{}", g.name);
        }
    }

    #[test]
    fn aiu_off_grows_program() {
        let g = models::paper_mbv1();
        let on = compile(&g, &ArchConfig::j3dai()).unwrap();
        let cfg_off = ArchConfig { aiu_enabled: false, ..ArchConfig::j3dai() };
        let off = compile(&g, &cfg_off).unwrap();
        assert!(
            off.program_bytes() > on.program_bytes(),
            "AIU must shrink programs: on={} off={}",
            on.program_bytes(),
            off.program_bytes()
        );
    }

    #[test]
    fn compile_traced_records_pass_spans() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let tel = Telemetry::new(true);
        let c = compile_traced(&g, &ArchConfig::j3dai(), Some(&tel)).unwrap();
        assert_eq!(c.total_macs(), g.total_macs());
        let tr = tel.take_trace();
        let names: Vec<&str> = tr.events.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(names, ["place_memory", "map_layers", "codegen", "host_schedule"]);
        assert!(tr.events.iter().all(|e| e.pid == COMPILER_PID));
        let text = tel.render_metrics();
        assert!(text.contains("j3dai_compile_pass_us_count{pass=\"codegen\"} 1"), "{text}");
    }

    #[test]
    fn scaled_config_still_conserves_macs() {
        let g = models::mobilenet_v1(1, 4, Shape::new(48, 64, 3), 100);
        for cl in [1, 3, 6, 8] {
            let cfg = ArchConfig::scaled(cl, 16, 8);
            let c = compile(&g, &cfg).unwrap();
            assert_eq!(c.total_macs(), g.total_macs(), "clusters={cl}");
        }
    }
}
