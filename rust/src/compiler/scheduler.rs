//! Scheduling solver — "looks for the best way to mask parameter loading.
//! At every execution step, it verifies if an additional memory bank is
//! available and explores multiple schedules to minimize execution time."
//! (paper §III-C2)
//!
//! The double-buffering decision itself is encoded by [`codegen`] in the
//! instruction order (prefetch next tile, then compute current, then
//! sync). This module owns the *host-side* schedule: per-layer descriptor
//! writes, sync-register polling and interrupt service, which the system
//! simulator charges as serial cycles between layers.

use crate::config::ArchConfig;
use crate::graph::{Graph, Op};

use super::HostStep;

/// Host cycles to write one layer descriptor set and arm the clusters.
/// (RISC-V store instructions over the system interconnect; measured-ish
/// constant, part of the Table I calibration.)
pub const HOST_DESCRIPTOR_CYCLES: u64 = 120;
/// Host cycles to service the end-of-layer interrupt and check status.
pub const HOST_SYNC_CYCLES: u64 = 80;
/// Extra descriptors for ops with two operands or reshaping.
pub const HOST_EXTRA_DESCRIPTOR: u64 = 40;

/// Produce the host schedule for a graph. Each step charges descriptor
/// writes + interrupt service plus the calibrated cross-cluster layer
/// barrier (EXPERIMENTS.md §Calibration).
pub fn host_schedule(g: &Graph, cfg: &ArchConfig) -> Vec<HostStep> {
    g.layers
        .iter()
        .map(|l| {
            let extra = match l.op {
                Op::Add => HOST_EXTRA_DESCRIPTOR,           // two source descriptors
                Op::Upsample2x { .. } => HOST_EXTRA_DESCRIPTOR, // strided copy descriptor
                _ => 0,
            };
            HostStep {
                layer: l.name.clone(),
                host_cycles: HOST_DESCRIPTOR_CYCLES + HOST_SYNC_CYCLES + extra + cfg.layer_barrier_cycles,
            }
        })
        .collect()
}

/// Total host cycles (all layers, serial).
pub fn host_total_cycles(steps: &[HostStep]) -> u64 {
    steps.iter().map(|s| s.host_cycles).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Shape;
    use crate::models;

    #[test]
    fn every_layer_gets_a_step() {
        let g = models::paper_mbv2();
        let steps = host_schedule(&g, &ArchConfig::j3dai());
        assert_eq!(steps.len(), g.layers.len());
        assert!(steps.iter().all(|s| s.host_cycles >= HOST_DESCRIPTOR_CYCLES));
    }

    #[test]
    fn adds_cost_more_host_work() {
        let g = models::paper_mbv2();
        let steps = host_schedule(&g, &ArchConfig::j3dai());
        let add = steps.iter().find(|s| s.layer.ends_with("/add")).unwrap();
        let conv = steps.iter().find(|s| s.layer.ends_with("/conv0")).unwrap();
        assert!(add.host_cycles > conv.host_cycles);
    }

    #[test]
    fn host_overhead_is_small_vs_compute() {
        // The host must not dominate latency (it orchestrates, not computes):
        // for MBv1 the paper's 4.96 ms = 992k cycles; host share < 2%.
        let g = models::paper_mbv1();
        let steps = host_schedule(&g, &ArchConfig::j3dai());
        // 29 layers x ~2.3k cycles barrier+descriptors ~ 67k of 992k (<8%)
        assert!(host_total_cycles(&steps) < 80_000);
        let _ = models::tinycnn(Shape::new(8, 8, 3), 4); // keep import used
    }
}
