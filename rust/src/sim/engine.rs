//! Cluster timing engine — executes one macro-op program on the two-engine
//! model (XFER = DMPA/DMA transfers, COMPUTE = PE array / ALU / NLU).
//!
//! Instructions issue in program order; each runs on its engine's timeline;
//! `sync` aligns both timelines (the step barrier codegen emits per tile).
//! This reproduces the double-buffering behaviour the paper's scheduler
//! aims for: within one step, the next tile's transfer overlaps the current
//! tile's MACs, so the step costs `max(xfer, compute)`.

use crate::config::ArchConfig;
use crate::isa::{Instr, Program};
use crate::power::Activity;
use crate::telemetry::pmu::{PmuCounters, StallReason};

/// Result of running one cluster program.
#[derive(Debug, Clone, Default)]
pub struct ClusterRun {
    /// Cycle at which the cluster halted.
    pub cycles: u64,
    /// Event profile for the energy model.
    pub activity: Activity,
    /// Cycles the compute engine was actually busy (utilization metric).
    pub compute_busy: u64,
    /// Cycles the transfer engine was busy.
    pub xfer_busy: u64,
    /// PMU counter bank: every cycle classified as busy, control or one of
    /// the stall reasons. Invariant (up to system-level `HostSync` added
    /// later): `pmu.total.accounted() == cycles`.
    pub pmu: PmuCounters,
}

/// Cycle cost of a compute instruction on this architecture.
pub fn compute_cycles(cfg: &ArchConfig, i: &Instr) -> u64 {
    let lanes = cfg.cluster_macs_per_cycle();
    match i {
        Instr::ConvTile { m, k, n, .. } => {
            // The PE array holds `lanes` output accumulators; each K step
            // broadcasts one operand column (weights via multicast register,
            // single-cycle path — §III-B2) and performs `lanes` MACs.
            let slots = (*m as u64 * *n as u64).div_ceil(lanes);
            slots * *k as u64 + cfg.op_setup_cycles + cfg.tile_epilogue_cycles
        }
        Instr::DwTile { h, w, c, .. } => {
            // channels ride the SIMD lanes; 9 taps per output position
            let slots = (*c as u64).div_ceil(lanes);
            slots * 9 * *h as u64 * *w as u64 + cfg.op_setup_cycles + cfg.tile_epilogue_cycles
        }
        Instr::AddTile { n } => (*n as u64).div_ceil(lanes) + cfg.op_setup_cycles,
        Instr::ActTile { n, .. } => (*n as u64).div_ceil(lanes) + cfg.op_setup_cycles,
        Instr::PoolTile { h, w, c } => {
            (*h as u64 * *w as u64 * *c as u64).div_ceil(lanes) + cfg.op_setup_cycles
        }
        Instr::RouteCfg { .. } => cfg.route_cfg_cycles,
        _ => 0,
    }
}

/// Cycle cost of a transfer instruction.
pub fn xfer_cycles(cfg: &ArchConfig, i: &Instr) -> u64 {
    match i {
        Instr::DmpaLoad { bytes, .. } | Instr::DmpaStore { bytes, .. } => cfg.dmpa_cycles(*bytes as u64),
        Instr::DmaLoad { bytes, .. } | Instr::DmaStore { bytes, .. } => cfg.dma_cycles(*bytes as u64),
        _ => 0,
    }
}

/// One instruction occupancy interval on a cluster engine, in cluster
/// cycles. Produced by [`run_cluster_traced`]; the system level converts
/// these to trace-event spans.
#[derive(Debug, Clone, PartialEq)]
pub struct InstrSpan {
    /// Instruction mnemonic (span label).
    pub label: &'static str,
    /// Which engine timeline the interval occupies.
    pub engine: crate::isa::Engine,
    /// Start cycle (inclusive).
    pub start: u64,
    /// End cycle (exclusive); `end - start` is the instruction's duration.
    pub end: u64,
    /// Bytes moved (transfer instructions only).
    pub bytes: u64,
    /// MACs performed (compute instructions only).
    pub macs: u64,
    /// Owning graph layer, from the preceding `layer.mark` (u32::MAX if none).
    pub layer: u32,
    /// Event-count delta of exactly this instruction — the energy model
    /// turns it into per-span joules. `cycles` is the span duration;
    /// `busy_cluster_cycles` counts compute-engine occupancy only (the
    /// controller/AGU/clock-tree energy is attributed to the compute
    /// timeline — see `telemetry::energy`).
    pub activity: Activity,
}

/// Where the traced engine delivers spans. `ENABLED` is a compile-time
/// constant, so the untraced instantiation ([`NullSink`]) monomorphizes to
/// exactly the old loop — disabled tracing costs nothing.
pub trait SpanSink {
    const ENABLED: bool;
    fn record(&mut self, span: InstrSpan);
}

/// The no-op sink backing [`run_cluster`].
pub struct NullSink;

impl SpanSink for NullSink {
    const ENABLED: bool = false;
    #[inline(always)]
    fn record(&mut self, _span: InstrSpan) {}
}

impl SpanSink for Vec<InstrSpan> {
    const ENABLED: bool = true;
    fn record(&mut self, span: InstrSpan) {
        self.push(span);
    }
}

/// One transfer-timeline interval tagged with the stall reason a compute
/// engine waiting on it reports, and the layer that issued the transfer.
struct XferSeg {
    start: u64,
    end: u64,
    reason: StallReason,
    layer: u32,
}

fn push_seg(segs: &mut Vec<XferSeg>, start: u64, end: u64, reason: StallReason, layer: u32) {
    if end > start {
        segs.push(XferSeg { start, end, reason, layer });
    }
}

/// Attribute the compute-idle window `[gap_start, gap_end)` to the stall
/// reasons of the transfer segments that cover it. Segments tile the
/// transfer timeline densely since the last sync, so the gap — which
/// starts at or after that sync — is always fully covered.
fn attribute_gap(pmu: &mut PmuCounters, segs: &[XferSeg], gap_start: u64, gap_end: u64) {
    for seg in segs {
        let s = seg.start.max(gap_start);
        let e = seg.end.min(gap_end);
        if e > s {
            pmu.stall(seg.layer, seg.reason, e - s);
        }
    }
}

fn run_cluster_impl<S: SpanSink>(
    cfg: &ArchConfig,
    prog: &Program,
    dma_penalty: u64,
    sink: &mut S,
) -> ClusterRun {
    let mut xfer_t: u64 = 0;
    let mut comp_t: u64 = 0;
    let mut act = Activity::default();
    let mut compute_busy = 0u64;
    let mut xfer_busy = 0u64;
    let mut cur_layer = u32::MAX;
    let mut pmu = PmuCounters::default();
    // transfer segments since the last sync — the PMU classifies compute
    // wait cycles by intersecting the wait window with these
    let mut segs: Vec<XferSeg> = Vec::new();

    for i in &prog.instrs {
        match i {
            Instr::Sync => {
                if comp_t < xfer_t {
                    attribute_gap(&mut pmu, &segs, comp_t, xfer_t);
                }
                segs.clear();
                let t = xfer_t.max(comp_t);
                xfer_t = t;
                comp_t = t;
            }
            Instr::Halt => break,
            Instr::LayerMark { id } => cur_layer = *id,
            Instr::AiuLoop { .. } => {
                // loop setup rides the control path: one cycle on compute
                comp_t += 1;
                pmu.ctrl(cur_layer, 1);
            }
            _ if i.engine() == crate::isa::Engine::Xfer => {
                let is_dma = matches!(i, Instr::DmaLoad { .. } | Instr::DmaStore { .. });
                let dur = xfer_cycles(cfg, i) * if is_dma { dma_penalty } else { 1 };
                let bytes = i.xfer_bytes();
                if is_dma {
                    // bus-arbitration share first (the penalty models the
                    // serialized shared bus), then the descriptor itself
                    let base = xfer_cycles(cfg, i);
                    let arb = (dma_penalty - 1) * base;
                    push_seg(&mut segs, xfer_t, xfer_t + arb, StallReason::NcbArb, cur_layer);
                    push_seg(&mut segs, xfer_t + arb, xfer_t + dur, StallReason::DmaWait, cur_layer);
                } else {
                    // DMPA: setup beats resolve L2 block conflicts, the
                    // remaining beats stream into the NCB weight buffer
                    let setup = cfg.dmpa_setup_cycles.min(dur);
                    push_seg(&mut segs, xfer_t, xfer_t + setup, StallReason::L2Bank, cur_layer);
                    push_seg(
                        &mut segs,
                        xfer_t + setup,
                        xfer_t + dur,
                        StallReason::WeightRefill,
                        cur_layer,
                    );
                }
                // per-instruction delta: the span carries it so the energy
                // model can attribute joules span-by-span
                let mut d = Activity { cycles: dur, ..Activity::default() };
                if is_dma {
                    d.dma_bytes = bytes;
                } else {
                    d.dmpa_bytes = bytes;
                }
                if i.crosses_tsv() {
                    d.tsv_bytes = bytes;
                }
                // every transferred byte lands in / leaves an NCB SRAM bank
                d.local_sram_bytes = bytes;
                if S::ENABLED {
                    sink.record(InstrSpan {
                        label: i.mnemonic(),
                        engine: crate::isa::Engine::Xfer,
                        start: xfer_t,
                        end: xfer_t + dur,
                        bytes,
                        macs: 0,
                        layer: cur_layer,
                        activity: d,
                    });
                }
                xfer_t += dur;
                xfer_busy += dur;
                act.merge_sequential(&d);
            }
            _ => {
                let dur = compute_cycles(cfg, i);
                let mut d = Activity {
                    cycles: dur,
                    busy_cluster_cycles: dur,
                    macs: i.macs(),
                    ..Activity::default()
                };
                match i {
                    Instr::AddTile { n } => d.alu_ops = *n as u64,
                    Instr::ActTile { n, .. } => d.alu_ops = *n as u64,
                    Instr::PoolTile { h, w, c } => d.alu_ops = *h as u64 * *w as u64 * *c as u64,
                    Instr::ConvTile { m, k, n, .. } => {
                        // operand reads from NCB SRAM: act row + weight col per MAC
                        // (banked SRAM services the SIMD lanes in parallel)
                        d.local_sram_bytes = *m as u64 * *k as u64 + *k as u64 * *n as u64;
                    }
                    Instr::DwTile { h, w, c, .. } => {
                        d.local_sram_bytes = *h as u64 * *w as u64 * *c as u64 * 2;
                    }
                    _ => {}
                }
                if S::ENABLED && dur > 0 {
                    sink.record(InstrSpan {
                        label: i.mnemonic(),
                        engine: crate::isa::Engine::Compute,
                        start: comp_t,
                        end: comp_t + dur,
                        bytes: 0,
                        macs: i.macs(),
                        layer: cur_layer,
                        activity: d,
                    });
                }
                comp_t += dur;
                compute_busy += dur;
                pmu.busy(cur_layer, dur);
                act.merge_sequential(&d);
            }
        }
    }
    // final wait: the transfer engine outlives the last compute op (a halt
    // without a trailing sync) — classify those cycles too
    if comp_t < xfer_t {
        attribute_gap(&mut pmu, &segs, comp_t, xfer_t);
    }
    let cycles = xfer_t.max(comp_t);
    act.cycles = cycles;
    act.busy_cluster_cycles = compute_busy.max(xfer_busy);
    ClusterRun { cycles, activity: act, compute_busy, xfer_busy, pmu }
}

/// Run one program; `dma_penalty` multiplies DMA cycles (shared-bus
/// contention across clusters, applied by the system level).
pub fn run_cluster(cfg: &ArchConfig, prog: &Program, dma_penalty: u64) -> ClusterRun {
    run_cluster_impl(cfg, prog, dma_penalty, &mut NullSink)
}

/// [`run_cluster`], also returning one [`InstrSpan`] per cycle-consuming
/// instruction. The `ClusterRun` is bit-identical to the untraced path.
pub fn run_cluster_traced(
    cfg: &ArchConfig,
    prog: &Program,
    dma_penalty: u64,
) -> (ClusterRun, Vec<InstrSpan>) {
    let mut spans = Vec::with_capacity(prog.instrs.len());
    let run = run_cluster_impl(cfg, prog, dma_penalty, &mut spans);
    (run, spans)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Space;

    fn cfg() -> ArchConfig {
        ArchConfig::j3dai()
    }

    #[test]
    fn conv_tile_cycles_ideal() {
        // one full 128-lane tile: m*n = 128 -> slots=1 -> k cycles + setup
        let c = cfg();
        let i = Instr::ConvTile { m: 2, k: 64, n: 64, first: true, last: true };
        assert_eq!(compute_cycles(&c, &i), 64 + c.op_setup_cycles + c.tile_epilogue_cycles);
    }

    #[test]
    fn overlap_makes_step_max_of_engines() {
        let c = cfg();
        let load = Instr::DmpaLoad { src: Space::L2Bottom, src_addr: 0, dst_addr: 0, bytes: 128 * 100 };
        let conv = Instr::ConvTile { m: 2, k: 200, n: 64, first: true, last: true };
        let prog = Program { instrs: vec![load.clone(), conv.clone(), Instr::Sync, Instr::Halt] };
        let r = run_cluster(&c, &prog, 1);
        let lx = xfer_cycles(&c, &load);
        let lc = compute_cycles(&c, &conv);
        assert_eq!(r.cycles, lx.max(lc));
        assert!(r.cycles < lx + lc, "engines must overlap");
        assert!(lx > c.dmpa_setup_cycles && lc > c.tile_epilogue_cycles);
    }

    #[test]
    fn sync_serializes() {
        let c = cfg();
        let load = Instr::DmpaLoad { src: Space::L2Bottom, src_addr: 0, dst_addr: 0, bytes: 1280 };
        let conv = Instr::ConvTile { m: 2, k: 64, n: 64, first: true, last: true };
        let prog = Program { instrs: vec![load.clone(), Instr::Sync, conv.clone(), Instr::Halt] };
        let r = run_cluster(&c, &prog, 1);
        assert_eq!(r.cycles, xfer_cycles(&c, &load) + compute_cycles(&c, &conv));
    }

    #[test]
    fn dma_penalty_scales_transfers() {
        let c = cfg();
        let load = Instr::DmaLoad { src: Space::L2Bottom, src_addr: 0, dst_addr: 0, bytes: 4096 };
        let prog = Program { instrs: vec![load, Instr::Halt] };
        let r1 = run_cluster(&c, &prog, 1);
        let r6 = run_cluster(&c, &prog, 6);
        assert_eq!(r6.cycles, r1.cycles * 6);
    }

    #[test]
    fn activity_accounts_bytes_and_macs() {
        let c = cfg();
        let prog = Program {
            instrs: vec![
                Instr::DmpaLoad { src: Space::L2Middle, src_addr: 0, dst_addr: 0, bytes: 1000 },
                Instr::ConvTile { m: 8, k: 16, n: 16, first: true, last: true },
                Instr::AddTile { n: 500 },
                Instr::Halt,
            ],
        };
        let r = run_cluster(&c, &prog, 1);
        assert_eq!(r.activity.dmpa_bytes, 1000);
        assert_eq!(r.activity.tsv_bytes, 1000);
        assert_eq!(r.activity.macs, 8 * 16 * 16);
        assert_eq!(r.activity.alu_ops, 500);
    }

    /// A hand-built two-tile program with the double-buffering shape codegen
    /// emits: load tile 0; sync; (compute tile 0 || load tile 1); sync;
    /// compute tile 1; store; halt.
    fn two_tile_program() -> Program {
        let load = |addr: u32| Instr::DmpaLoad {
            src: Space::L2Bottom,
            src_addr: addr,
            dst_addr: 0,
            bytes: 4096,
        };
        let conv = Instr::ConvTile { m: 8, k: 64, n: 16, first: true, last: true };
        Program {
            instrs: vec![
                Instr::LayerMark { id: 0 },
                load(0x0),
                Instr::Sync,
                conv.clone(),
                load(0x1000),
                Instr::Sync,
                Instr::LayerMark { id: 1 },
                conv,
                Instr::DmpaStore { dst: Space::L2Middle, dst_addr: 0, src_addr: 0, bytes: 512 },
                Instr::Sync,
                Instr::Halt,
            ],
        }
    }

    #[test]
    fn busy_cycles_account_for_total() {
        let c = cfg();
        let prog = two_tile_program();
        let r = run_cluster(&c, &prog, 1);
        // compute idle time is exactly total minus busy; both engines fit
        // inside the run
        assert!(r.compute_busy <= r.cycles);
        assert!(r.xfer_busy <= r.cycles);
        let idle = r.cycles - r.compute_busy;
        assert_eq!(r.compute_busy + idle, r.cycles);
        assert!(r.compute_busy > 0 && r.xfer_busy > 0);
        // each sync-delimited step costs max(xfer, compute), so the whole
        // run is at most the sum of busies and at least the larger one
        assert!(r.cycles <= r.compute_busy + r.xfer_busy);
        assert!(r.cycles >= r.compute_busy.max(r.xfer_busy));
    }

    #[test]
    fn two_tile_overlap_step_is_max_of_engines() {
        let c = cfg();
        let prog = two_tile_program();
        let r = run_cluster(&c, &prog, 1);
        let load_cyc = xfer_cycles(&c, &prog.instrs[1]);
        let conv_cyc = compute_cycles(&c, &prog.instrs[3]);
        let store_cyc = xfer_cycles(&c, &prog.instrs[8]);
        // step 1: load alone; step 2: conv || load -> max; step 3: conv || store -> max
        let expect = load_cyc + conv_cyc.max(load_cyc) + conv_cyc.max(store_cyc);
        assert_eq!(r.cycles, expect);
        assert_eq!(r.compute_busy, 2 * conv_cyc);
        assert_eq!(r.xfer_busy, 2 * load_cyc + store_cyc);
    }

    #[test]
    fn traced_run_matches_untraced_and_spans_cover_busy() {
        let c = cfg();
        let prog = two_tile_program();
        let plain = run_cluster(&c, &prog, 1);
        let (traced, spans) = run_cluster_traced(&c, &prog, 1);
        assert_eq!(plain.cycles, traced.cycles);
        assert_eq!(plain.compute_busy, traced.compute_busy);
        assert_eq!(plain.xfer_busy, traced.xfer_busy);
        assert_eq!(plain.activity.macs, traced.activity.macs);

        // span durations per engine sum exactly to the busy counters
        let sum = |e: crate::isa::Engine| {
            spans
                .iter()
                .filter(|s| s.engine == e)
                .map(|s| s.end - s.start)
                .sum::<u64>()
        };
        assert_eq!(sum(crate::isa::Engine::Compute), traced.compute_busy);
        assert_eq!(sum(crate::isa::Engine::Xfer), traced.xfer_busy);
        // 2 convs + 2 loads + 1 store, each attributed to its layer.mark
        assert_eq!(spans.len(), 5);
        assert!(spans.iter().all(|s| s.end > s.start));
        assert_eq!(spans.iter().filter(|s| s.layer == 0).count(), 3);
        assert_eq!(spans.iter().filter(|s| s.layer == 1).count(), 2);
        // spans on one engine never overlap (sorted issue order)
        for e in [crate::isa::Engine::Compute, crate::isa::Engine::Xfer] {
            let mut last_end = 0;
            for s in spans.iter().filter(|s| s.engine == e) {
                assert!(s.start >= last_end);
                last_end = s.end;
            }
        }
    }

    #[test]
    fn span_activity_deltas_sum_to_run_activity() {
        let c = cfg();
        let prog = two_tile_program();
        let (run, spans) = run_cluster_traced(&c, &prog, 1);
        let mut acc = Activity::default();
        for s in &spans {
            acc.merge_sequential(&s.activity);
        }
        assert_eq!(acc.macs, run.activity.macs);
        assert_eq!(acc.local_sram_bytes, run.activity.local_sram_bytes);
        assert_eq!(acc.dmpa_bytes, run.activity.dmpa_bytes);
        assert_eq!(acc.dma_bytes, run.activity.dma_bytes);
        assert_eq!(acc.tsv_bytes, run.activity.tsv_bytes);
        assert_eq!(acc.alu_ops, run.activity.alu_ops);
        // controller energy rides the compute timeline: per-span busy
        // cycles sum to the compute engine's occupancy, not the cluster max
        assert_eq!(acc.busy_cluster_cycles, run.compute_busy);
    }

    #[test]
    fn layer_mark_costs_nothing() {
        let c = cfg();
        let mut marked = two_tile_program();
        let plain = Program {
            instrs: marked
                .instrs
                .iter()
                .filter(|i| !matches!(i, Instr::LayerMark { .. }))
                .cloned()
                .collect(),
        };
        let rm = run_cluster(&c, &marked, 1);
        let rp = run_cluster(&c, &plain, 1);
        assert_eq!(rm.cycles, rp.cycles);
        assert_eq!(rm.activity.macs, rp.activity.macs);
        // and it encodes/decodes like any other word
        marked.instrs.truncate(1);
        let bytes = marked.assemble();
        assert_eq!(Program::disassemble(&bytes).unwrap().instrs, marked.instrs);
    }

    #[test]
    fn pmu_accounts_every_cycle() {
        let c = cfg();
        let prog = two_tile_program();
        let r = run_cluster(&c, &prog, 1);
        assert_eq!(r.pmu.total.accounted(), r.cycles, "busy+ctrl+stalls must equal cycles");
        assert_eq!(r.pmu.total.busy, r.compute_busy);
        // engine-level attribution never produces host_sync (system adds it)
        assert_eq!(r.pmu.total.stalls[crate::telemetry::StallReason::HostSync.index()], 0);
        // per-layer banks partition the total
        let per: u64 = r.pmu.per_layer.values().map(|b| b.accounted()).sum();
        assert_eq!(per, r.pmu.total.accounted());
        assert_eq!(r.pmu.per_layer.len(), 2);
        // a DMPA-fed program stalls on weight refill / L2 setup, not DMA
        assert!(r.pmu.total.stalls[crate::telemetry::StallReason::WeightRefill.index()] > 0);
        assert_eq!(r.pmu.total.stalls[crate::telemetry::StallReason::DmaWait.index()], 0);
    }

    #[test]
    fn pmu_splits_dma_wait_from_arbitration() {
        let c = cfg();
        let load = Instr::DmaLoad { src: Space::L2Bottom, src_addr: 0, dst_addr: 0, bytes: 4096 };
        let prog = Program { instrs: vec![load.clone(), Instr::Halt] };
        let base = xfer_cycles(&c, &load);

        let r1 = run_cluster(&c, &prog, 1);
        assert_eq!(r1.pmu.total.stalls[crate::telemetry::StallReason::DmaWait.index()], base);
        assert_eq!(r1.pmu.total.stalls[crate::telemetry::StallReason::NcbArb.index()], 0);
        assert_eq!(r1.pmu.total.accounted(), r1.cycles);

        let r6 = run_cluster(&c, &prog, 6);
        assert_eq!(r6.pmu.total.stalls[crate::telemetry::StallReason::DmaWait.index()], base);
        assert_eq!(r6.pmu.total.stalls[crate::telemetry::StallReason::NcbArb.index()], 5 * base);
        assert_eq!(r6.pmu.total.accounted(), r6.cycles);
    }

    #[test]
    fn pmu_overlapped_compute_hides_transfer_stalls() {
        let c = cfg();
        // transfer shorter than the overlapped compute: zero stall cycles
        let load = Instr::DmpaLoad { src: Space::L2Bottom, src_addr: 0, dst_addr: 0, bytes: 1280 };
        let conv = Instr::ConvTile { m: 2, k: 200, n: 64, first: true, last: true };
        assert!(xfer_cycles(&c, &load) < compute_cycles(&c, &conv));
        let prog = Program { instrs: vec![load, conv, Instr::Sync, Instr::Halt] };
        let r = run_cluster(&c, &prog, 1);
        assert_eq!(r.pmu.total.stall_total(), 0);
        assert_eq!(r.pmu.total.accounted(), r.cycles);
    }

    #[test]
    fn pmu_identical_between_traced_and_untraced() {
        let c = cfg();
        let prog = two_tile_program();
        let plain = run_cluster(&c, &prog, 1);
        let (traced, _) = run_cluster_traced(&c, &prog, 1);
        assert_eq!(plain.pmu, traced.pmu);
    }

    #[test]
    fn dw_tile_efficiency_depends_on_channels() {
        // c=128 fills the lanes; c=16 wastes 7/8 of them
        let c = cfg();
        let full = Instr::DwTile { h: 4, w: 4, c: 128, stride: 1 };
        let thin = Instr::DwTile { h: 4, w: 4, c: 16, stride: 1 };
        assert_eq!(compute_cycles(&c, &full), compute_cycles(&c, &thin));
        // same cycles, 8x fewer MACs -> 8x lower efficiency
        assert_eq!(full.macs(), 8 * thin.macs());
    }
}
