//! Host processor model — the RISC-V 32b CPU of §III-B1: "Synchronization
//! between the IP and the host is done through a set of registers and
//! optional interrupt signals."
//!
//! An event-level state machine over the memory-mapped control/status
//! register file each cluster exposes: the host arms descriptors, starts
//! clusters, and either polls the status registers or blocks on the
//! interrupt line. The scheduler's per-layer host cycles come from the
//! descriptor/sync costs modeled here.

/// Memory-mapped control/status registers of one cluster (§III-B2: "The
/// local controller embeds all control and status registers accessible
/// from the host processor through the system interconnect").
#[derive(Debug, Clone, Default)]
pub struct ClusterCsr {
    /// program base address in L2
    pub prog_addr: u32,
    /// program length in 16-byte words
    pub prog_len: u32,
    /// run flag (host sets, controller clears at Halt)
    pub running: bool,
    /// sticky done flag (cleared by host read)
    pub done: bool,
    /// interrupt enable
    pub irq_en: bool,
    /// error code (0 = ok)
    pub error: u32,
}

/// Host-visible interrupt line state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Irq {
    Idle,
    Pending { cluster: usize },
}

/// The host state machine.
#[derive(Debug)]
pub struct Host {
    pub csrs: Vec<ClusterCsr>,
    pub irq: Irq,
    /// cycles spent on descriptor writes / register polls
    pub cycles: u64,
}

/// Host cycle costs (32-bit stores/loads over the system interconnect).
pub const CSR_WRITE_CYCLES: u64 = 6;
pub const CSR_READ_CYCLES: u64 = 6;
pub const IRQ_SERVICE_CYCLES: u64 = 40;

impl Host {
    pub fn new(clusters: usize) -> Self {
        Host { csrs: vec![ClusterCsr::default(); clusters], irq: Irq::Idle, cycles: 0 }
    }

    /// Program a cluster's descriptor (prog base + length + irq enable).
    pub fn arm(&mut self, cluster: usize, prog_addr: u32, prog_len: u32, irq_en: bool) {
        let csr = &mut self.csrs[cluster];
        csr.prog_addr = prog_addr;
        csr.prog_len = prog_len;
        csr.irq_en = irq_en;
        csr.done = false;
        csr.error = 0;
        self.cycles += 3 * CSR_WRITE_CYCLES; // addr, len, ctrl stores
    }

    /// Start one cluster (single control-register store).
    pub fn start(&mut self, cluster: usize) {
        self.csrs[cluster].running = true;
        self.cycles += CSR_WRITE_CYCLES;
    }

    /// The accelerator side signals completion (called by the system sim).
    pub fn cluster_halted(&mut self, cluster: usize, error: u32) {
        let csr = &mut self.csrs[cluster];
        csr.running = false;
        csr.done = true;
        csr.error = error;
        if csr.irq_en && self.irq == Irq::Idle {
            self.irq = Irq::Pending { cluster };
        }
    }

    /// Poll until every cluster is done (no interrupts): each poll is one
    /// status read per still-running cluster. Returns polls performed.
    pub fn poll_all_done(&mut self, max_polls: u64) -> crate::Result<u64> {
        // in the event model all clusters have already halted or not; a
        // poll round reads every not-yet-done CSR
        let mut polls = 0;
        for _ in 0..max_polls {
            let pending: Vec<usize> =
                (0..self.csrs.len()).filter(|&i| !self.csrs[i].done).collect();
            self.cycles += pending.len() as u64 * CSR_READ_CYCLES;
            polls += 1;
            if pending.is_empty() {
                return Ok(polls);
            }
            // event model: nothing changes between polls unless the sim
            // advances; treat remaining as stuck
            anyhow::bail!("clusters {pending:?} never halted");
        }
        anyhow::bail!("poll budget exhausted")
    }

    /// Service the pending interrupt: read status, clear, return cluster.
    pub fn service_irq(&mut self) -> Option<usize> {
        match self.irq {
            Irq::Idle => None,
            Irq::Pending { cluster } => {
                self.irq = Irq::Idle;
                self.csrs[cluster].done = false; // sticky-clear on read
                self.cycles += IRQ_SERVICE_CYCLES;
                Some(cluster)
            }
        }
    }

    /// All clusters idle?
    pub fn all_idle(&self) -> bool {
        self.csrs.iter().all(|c| !c.running)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arm_start_halt_roundtrip() {
        let mut h = Host::new(6);
        h.arm(0, 0x1000, 64, true);
        h.start(0);
        assert!(h.csrs[0].running);
        assert!(!h.all_idle());
        h.cluster_halted(0, 0);
        assert!(h.all_idle());
        assert!(h.csrs[0].done);
        assert_eq!(h.irq, Irq::Pending { cluster: 0 });
    }

    #[test]
    fn irq_service_clears_sticky_done() {
        let mut h = Host::new(2);
        h.arm(1, 0, 1, true);
        h.start(1);
        h.cluster_halted(1, 0);
        assert_eq!(h.service_irq(), Some(1));
        assert!(!h.csrs[1].done);
        assert_eq!(h.service_irq(), None);
    }

    #[test]
    fn polling_counts_reads() {
        let mut h = Host::new(3);
        for c in 0..3 {
            h.arm(c, 0, 1, false);
            h.start(c);
            h.cluster_halted(c, 0);
        }
        let before = h.cycles;
        let polls = h.poll_all_done(10).unwrap();
        assert_eq!(polls, 1);
        // all were done: one round of zero pending reads
        assert_eq!(h.cycles, before);
    }

    #[test]
    fn stuck_cluster_detected() {
        let mut h = Host::new(2);
        h.arm(0, 0, 1, false);
        h.start(0); // never halts
        assert!(h.poll_all_done(4).is_err());
    }

    #[test]
    fn error_code_propagates() {
        let mut h = Host::new(1);
        h.arm(0, 0, 1, true);
        h.start(0);
        h.cluster_halted(0, 7);
        assert_eq!(h.csrs[0].error, 7);
    }

    #[test]
    fn descriptor_cost_matches_scheduler_budget() {
        // the scheduler's HOST_DESCRIPTOR_CYCLES must cover arm+start for
        // all 6 clusters of one layer
        let mut h = Host::new(6);
        for c in 0..6 {
            h.arm(c, 0, 1, true);
            h.start(c);
        }
        assert!(h.cycles <= crate::compiler::scheduler::HOST_DESCRIPTOR_CYCLES + 100,
            "host cycles {} vs budget", h.cycles);
    }
}
