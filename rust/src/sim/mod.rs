//! Simulator of the J3DAI digital system.
//!
//! Two complementary views of the same machine:
//!
//! - **Timed** ([`engine`], [`system`]): executes the compiled per-cluster
//!   macro-op programs on a two-engine (transfer/compute) timing model with
//!   DMPA/DMA/TSV bandwidths, per-op controller overhead and host
//!   orchestration — produces cycle counts and the [`crate::power::Activity`]
//!   event profile for the energy model. This is what regenerates the
//!   paper's latency / MAC-efficiency / power rows.
//!
//! - **Functional** ([`functional`], [`pe`]): interprets the quantized graph
//!   with the exact integer semantics of the PE datapath (9-bit multiply,
//!   32-bit accumulate, fixed-point requantization, PWL NLU). Its outputs
//!   are compared byte-for-byte against the JAX/Pallas golden artifacts via
//!   the PJRT runtime — the three-layer equivalence proof.

pub mod engine;
pub mod functional;
pub mod host;
pub mod l2;
pub mod ncb;
pub mod pe;
pub mod system;

pub use engine::{run_cluster_traced, ClusterRun, InstrSpan};
pub use system::{
    default_threads, sample_timeseries, simulate, simulate_compiled,
    simulate_compiled_threads, simulate_compiled_traced, simulate_compiled_traced_threads,
    simulate_threads, simulate_traced, simulate_traced_threads, LayerStats, SimResult, SimTrace,
};
