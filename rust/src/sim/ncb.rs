//! Neural Computing Block — multi-banked SRAM + local router (§III-B3).
//!
//! "The multi-bank SRAMs are composed of independent memories. No specific
//! memory bank is dedicated to filter parameters or feature maps data."
//! "the local router module performs on-the-fly operations to transfer data
//! between memories and PEs in a single cycle. It supports neighbor
//! accesses, multi-cast transfers, and bit-shifting for data alignment
//! between PEs and can introduce zeros or ones for padding operations."
//!
//! This module is the functional model of those primitives: a banked SRAM
//! with conflict accounting, and the router's per-cycle lane-vector
//! operations. The cycle engine charges their timing; the tests here pin
//! their semantics.

/// One NCB's banked SRAM. Flattened address space striped across banks
/// word-by-word (the "fully generic" organization).
#[derive(Debug, Clone)]
pub struct BankedSram {
    banks: usize,
    data: Vec<u8>,
    /// read/write event counters per bank (for conflict metrics)
    accesses: Vec<u64>,
    /// cumulative serialization cycles lost to same-bank collisions in
    /// parallel bursts (the PMU's bank-conflict taxonomy at NCB level)
    conflict_cycles: u64,
}

impl BankedSram {
    pub fn new(bytes: usize, banks: usize) -> Self {
        assert!(banks > 0 && bytes % banks == 0);
        BankedSram { banks, data: vec![0; bytes], accesses: vec![0; banks], conflict_cycles: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.data.len()
    }

    #[inline]
    fn bank_of(&self, addr: usize) -> usize {
        addr % self.banks
    }

    pub fn write(&mut self, addr: usize, bytes: &[u8]) {
        for (i, &b) in bytes.iter().enumerate() {
            let a = addr + i;
            let bank = a % self.banks;
            self.accesses[bank] += 1;
            self.data[a] = b;
        }
    }

    pub fn read(&mut self, addr: usize, len: usize) -> &[u8] {
        for i in 0..len {
            let bank = (addr + i) % self.banks;
            self.accesses[bank] += 1;
        }
        &self.data[addr..addr + len]
    }

    /// Cycles to service `lanes` simultaneous single-byte reads at the
    /// given addresses: reads hitting the same bank serialize.
    pub fn parallel_read_cycles(&self, addrs: &[usize]) -> u64 {
        let mut per_bank = vec![0u64; self.banks];
        for &a in addrs {
            per_bank[self.bank_of(a)] += 1;
        }
        per_bank.into_iter().max().unwrap_or(0)
    }

    /// Service one lanes-wide parallel read burst, bumping the per-bank
    /// access counters and accumulating the excess serialization cycles
    /// (cycles beyond the conflict-free single cycle). Returns the burst's
    /// total cycles. This is the functional-model counterpart of the cycle
    /// engine's `ncb_arb`/`l2_bank` PMU stall reasons.
    pub fn service_parallel_read(&mut self, addrs: &[usize]) -> u64 {
        for &a in addrs {
            let bank = self.bank_of(a);
            self.accesses[bank] += 1;
        }
        let cycles = self.parallel_read_cycles(addrs);
        self.conflict_cycles += cycles.saturating_sub(1);
        cycles
    }

    /// Cumulative serialization cycles lost to bank conflicts across every
    /// burst serviced through [`Self::service_parallel_read`].
    pub fn conflict_cycles(&self) -> u64 {
        self.conflict_cycles
    }

    pub fn accesses(&self) -> &[u64] {
        &self.accesses
    }
}

/// Padding fill values the router can inject ("zeros or ones").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PadFill {
    Zeros,
    Ones,
    /// zero in the centered domain = the activation zero point
    ZeroPoint(u8),
}

impl PadFill {
    fn value(self) -> u8 {
        match self {
            PadFill::Zeros => 0x00,
            PadFill::Ones => 0xFF,
            PadFill::ZeroPoint(zp) => zp,
        }
    }
}

/// The local router's single-cycle lane-vector operations over the PE row.
#[derive(Debug, Clone)]
pub struct LocalRouter {
    pub lanes: usize,
}

impl LocalRouter {
    pub fn new(lanes: usize) -> Self {
        LocalRouter { lanes }
    }

    /// Neighbor access: shift the lane vector by `offset` (positive = take
    /// from higher lane), injecting `fill` at the edge — the 3x3 halo
    /// primitive for depthwise convolution.
    pub fn neighbor(&self, v: &[u8], offset: isize, fill: PadFill) -> Vec<u8> {
        assert_eq!(v.len(), self.lanes);
        (0..self.lanes as isize)
            .map(|i| {
                let j = i + offset;
                if j < 0 || j >= self.lanes as isize { fill.value() } else { v[j as usize] }
            })
            .collect()
    }

    /// Multicast: broadcast one source lane to every PE in a single cycle —
    /// "helpful for sending the parameters to multiple PEs in a single
    /// cycle".
    pub fn multicast(&self, v: &[u8], src_lane: usize) -> Vec<u8> {
        assert!(src_lane < self.lanes);
        vec![v[src_lane]; self.lanes]
    }

    /// Bit-shift alignment between PEs: every lane shifted by `bits`
    /// (used to realign sub-byte packed operands).
    pub fn align(&self, v: &[u8], bits: u32, left: bool) -> Vec<u8> {
        v.iter().map(|&b| if left { b << bits } else { b >> bits }).collect()
    }

    /// Mix: select per lane from two sources by mask — "advanced routing
    /// features allow mixing of data coming from multiple sources".
    pub fn mix(&self, a: &[u8], b: &[u8], take_b: &[bool]) -> Vec<u8> {
        assert!(a.len() == self.lanes && b.len() == self.lanes && take_b.len() == self.lanes);
        (0..self.lanes).map(|i| if take_b[i] { b[i] } else { a[i] }).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sram_stripes_across_banks() {
        let mut s = BankedSram::new(64, 4);
        s.write(0, &[1, 2, 3, 4, 5]);
        assert_eq!(s.read(0, 5), &[1, 2, 3, 4, 5]);
        // 5 sequential bytes touch banks 0..3 then 0 again (on write + read)
        assert_eq!(s.accesses()[0], 4);
        assert_eq!(s.accesses()[1], 2);
    }

    #[test]
    fn conflict_free_parallel_reads_cost_one_cycle() {
        let s = BankedSram::new(64, 4);
        // addresses 0,1,2,3 hit distinct banks
        assert_eq!(s.parallel_read_cycles(&[0, 1, 2, 3]), 1);
        // all in bank 0 serialize
        assert_eq!(s.parallel_read_cycles(&[0, 4, 8, 12]), 4);
        // mixed: worst bank dominates
        assert_eq!(s.parallel_read_cycles(&[0, 4, 1, 2]), 2);
    }

    #[test]
    fn serviced_bursts_accumulate_conflict_cycles() {
        let mut s = BankedSram::new(64, 4);
        // conflict-free burst: one cycle, no excess
        assert_eq!(s.service_parallel_read(&[0, 1, 2, 3]), 1);
        assert_eq!(s.conflict_cycles(), 0);
        // fully serialized burst: 4 cycles, 3 of them excess
        assert_eq!(s.service_parallel_read(&[0, 4, 8, 12]), 4);
        assert_eq!(s.conflict_cycles(), 3);
        // partial conflict adds one more excess cycle
        assert_eq!(s.service_parallel_read(&[0, 4, 1, 2]), 2);
        assert_eq!(s.conflict_cycles(), 4);
        // bank-0 access counter saw all the bank-0 addresses above
        assert_eq!(s.accesses()[0], 6);
    }

    #[test]
    fn neighbor_access_with_padding() {
        let r = LocalRouter::new(4);
        let v = [10, 20, 30, 40];
        assert_eq!(r.neighbor(&v, 1, PadFill::Zeros), vec![20, 30, 40, 0]);
        assert_eq!(r.neighbor(&v, -1, PadFill::Ones), vec![255, 10, 20, 30]);
        assert_eq!(r.neighbor(&v, -1, PadFill::ZeroPoint(128)), vec![128, 10, 20, 30]);
        assert_eq!(r.neighbor(&v, 0, PadFill::Zeros), v.to_vec());
    }

    #[test]
    fn multicast_fills_all_lanes() {
        let r = LocalRouter::new(8);
        let v = [1, 2, 3, 4, 5, 6, 7, 8];
        assert_eq!(r.multicast(&v, 2), vec![3; 8]);
    }

    #[test]
    fn align_shifts_each_lane() {
        let r = LocalRouter::new(2);
        assert_eq!(r.align(&[0b1000_0001, 0b0000_1111], 4, false), vec![0b1000, 0b0000]);
        assert_eq!(r.align(&[0b0000_0011, 0b0000_0001], 2, true), vec![0b1100, 0b0100]);
    }

    #[test]
    fn mix_selects_per_lane() {
        let r = LocalRouter::new(3);
        assert_eq!(r.mix(&[1, 2, 3], &[9, 8, 7], &[false, true, false]), vec![1, 8, 3]);
    }

    #[test]
    fn dwconv_row_via_neighbor_matches_direct() {
        // The 1D slice of the depthwise conv: y[i] = sum_d x[i+d-1]*w[d]
        // computed through the router's neighbor primitive must equal the
        // direct indexing form.
        let r = LocalRouter::new(8);
        let x: Vec<u8> = (1..=8).map(|v| (v * 13) as u8).collect();
        let w = [2i32, -3, 1];
        let zp = 0u8;
        let mut acc = vec![0i32; 8];
        for (d, &wd) in w.iter().enumerate() {
            let tap = r.neighbor(&x, d as isize - 1, PadFill::ZeroPoint(zp));
            for i in 0..8 {
                acc[i] += tap[i] as i32 * wd;
            }
        }
        for i in 0..8 {
            let mut want = 0i32;
            for (d, &wd) in w.iter().enumerate() {
                let j = i as isize + d as isize - 1;
                let xv = if j < 0 || j >= 8 { zp as i32 } else { x[j as usize] as i32 };
                want += xv * wd;
            }
            assert_eq!(acc[i], want, "lane {i}");
        }
    }
}
