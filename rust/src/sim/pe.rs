//! Processing-element datapath model — the integer semantics of one J3DAI
//! PE: 9-bit multiplier, 32-bit accumulator, ALU, and the non-linear
//! operation unit (a 16-segment piecewise-linear function table).
//!
//! Bit-exact twin of `python/compile/kernels/` (see the parity tests and
//! the PJRT cross-check in `rust/tests/golden_equivalence.rs`).

use crate::quant::Requant;

/// One multiply-accumulate step: `(a - zp)` is the 9-bit signed activation
/// operand, `w` the 8-bit weight. Panics in debug builds if the operand
/// leaves the 9-bit range (it cannot, by construction).
#[inline(always)]
pub fn mac(acc: i32, a: u8, zp: i32, w: i8) -> i32 {
    let xa = a as i32 - zp;
    debug_assert!((-256..=255).contains(&xa), "9-bit operand range violated");
    acc + xa * w as i32
}

/// The NLU's PWL sigmoid table (round(sigmoid(x0/48)*255)) — shared with
/// `python/compile/kernels/elemwise.py` (NLU_X0 / NLU_BASE / NLU_SLOPE).
pub const NLU_BASE: [i32; 16] = [1, 2, 5, 9, 17, 30, 53, 86, 128, 168, 202, 225, 238, 246, 250, 253];

/// Segment start points: -256 + 32*i.
#[inline]
fn nlu_x0(seg: usize) -> i32 {
    -256 + 32 * seg as i32
}

/// Q8 slopes derived from consecutive base points (next of last = 254).
#[inline]
fn nlu_slope(seg: usize) -> i32 {
    let next = if seg == 15 { 254 } else { NLU_BASE[seg + 1] };
    (next - NLU_BASE[seg]) * 256 / 32
}

/// PWL sigmoid on a uint8 code with zero point `zp`.
#[inline]
pub fn nlu_sigmoid(x: u8, zp: i32) -> u8 {
    let xv = x as i32 - zp; // [-255, 255]
    let seg = (((xv + 256) >> 5).clamp(0, 15)) as usize;
    let y = NLU_BASE[seg] + ((nlu_slope(seg) * (xv - nlu_x0(seg))) >> 8);
    y.clamp(0, 255) as u8
}

/// Requantize an accumulator through the shared fixed-point contract.
#[inline(always)]
pub fn requant(acc: i32, rq: &Requant) -> u8 {
    rq.apply(acc)
}

/// Integer global-average step: `(sum + n/2) / n` over uint8 codes.
#[inline]
pub fn avg_round(sum: i64, n: i64) -> u8 {
    (((sum + n / 2) / n).clamp(0, 255)) as u8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_is_nine_bit_times_eight_bit() {
        assert_eq!(mac(0, 255, 0, 63), 255 * 63);
        assert_eq!(mac(0, 0, 255, -64), -255 * -64);
        assert_eq!(mac(10, 128, 128, 5), 10);
    }

    #[test]
    fn nlu_monotone_and_bounded() {
        let mut prev = 0u8;
        for x in 0..=255u16 {
            let y = nlu_sigmoid(x as u8, 128);
            assert!(y >= prev, "not monotone at {x}");
            prev = y;
        }
        assert!(nlu_sigmoid(0, 128) <= 30); // sigmoid(-128/48) ~ 0.065
        assert!(nlu_sigmoid(255, 128) >= 225);
        assert!(nlu_sigmoid(0, 255) <= 4); // full 9-bit swing
        assert!(nlu_sigmoid(255, 0) >= 250);
    }

    #[test]
    fn nlu_midpoint_near_half() {
        let y = nlu_sigmoid(128, 128) as i32;
        assert!((y - 128).abs() <= 25, "sigmoid(0) ~ 0.5: got {y}");
    }

    #[test]
    fn avg_round_matches_python() {
        assert_eq!(avg_round(0, 4), 0);
        assert_eq!(avg_round(2, 4), 1); // (2+2)/4
        assert_eq!(avg_round(1, 4), 0); // (1+2)/4
        assert_eq!(avg_round(255 * 9, 9), 255);
    }
}
