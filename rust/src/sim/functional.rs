//! Functional interpreter — executes a quantized [`Graph`] with the exact
//! PE integer semantics, materializing weights from the shared PRNG
//! streams. Byte-for-byte equivalent to the JAX/Pallas golden models
//! (proven against the PJRT artifacts in `rust/tests/golden_equivalence.rs`).

use crate::graph::{Graph, Op, Shape, INPUT};
use crate::quant::{self, weights, QAdd, Requant};
use crate::sim::pe;

/// A uint8 activation tensor in HWC layout.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Shape,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn new(shape: Shape, data: Vec<u8>) -> Self {
        assert_eq!(shape.elems(), data.len());
        Tensor { shape, data }
    }

    #[inline]
    fn at(&self, y: usize, x: usize, c: usize) -> u8 {
        self.data[(y * self.shape.w + x) * self.shape.c + c]
    }
}

/// Execute the graph on an input frame; returns every layer's output
/// (the last entry is the network output).
pub fn run(g: &Graph, input: &Tensor) -> Vec<Tensor> {
    assert_eq!(input.shape, g.input, "input shape mismatch");
    let mut outs: Vec<Tensor> = Vec::with_capacity(g.layers.len());
    for l in &g.layers {
        let get = |i: usize| -> &Tensor { if i == INPUT { input } else { &outs[i] } };
        let x = get(l.inputs[0]);
        let y = match &l.op {
            Op::Conv { kh, kw, cout, stride, relu } => conv(&l.name, x, *kh, *kw, *cout, *stride, *relu),
            Op::DwConv { stride } => dwconv(&l.name, x, *stride),
            Op::Dense { out } => dense(&l.name, x, *out),
            Op::Add => qadd(x, get(l.inputs[1])),
            Op::GlobalAvgPool => avgpool(x),
            Op::Upsample2x { to_h, to_w } => upsample(x, *to_h, *to_w),
            Op::NluSigmoid => nlu(x),
        };
        debug_assert_eq!(y.shape, l.out_shape, "shape mismatch at {}", l.name);
        outs.push(y);
    }
    outs
}

/// Convenience: run and return only the final output.
pub fn run_final(g: &Graph, input: &Tensor) -> Tensor {
    run(g, input).pop().expect("empty graph")
}

fn rq_for(k: usize, relu: bool) -> Requant {
    quant::requant_for_reduction(k, relu, false)
}

fn conv(name: &str, x: &Tensor, kh: usize, kw: usize, cout: usize, stride: usize, relu: bool) -> Tensor {
    let (h, w, cin) = (x.shape.h, x.shape.w, x.shape.c);
    let k = kh * kw * cin;
    let wq = weights::gen_weights_i8(&format!("{name}/w"), k * cout);
    let bias = weights::gen_bias_i32(name, cout);
    let rq = rq_for(k, relu);
    let (ph, pw) = ((kh - 1) / 2, (kw - 1) / 2);
    let oh = (h + 2 * ph - kh) / stride + 1;
    let ow = (w + 2 * pw - kw) / stride + 1;
    let zp = quant::ZP;
    let mut out = vec![0u8; oh * ow * cout];
    // co-innermost accumulation: the weight layout (kh, kw, cin, cout) is
    // contiguous in co, so the inner loop streams both operands linearly —
    // the software analog of the multicast register feeding all 8 PEs of an
    // NCB the same activation while each PE owns one output channel.
    let mut acc = vec![0i32; cout];
    for oy in 0..oh {
        for ox in 0..ow {
            let base_y = (oy * stride) as isize - ph as isize;
            let base_x = (ox * stride) as isize - pw as isize;
            acc.copy_from_slice(&bias);
            for dy in 0..kh {
                let yy = base_y + dy as isize;
                if yy < 0 || yy >= h as isize {
                    continue; // padded taps contribute (zp - zp) * w = 0
                }
                for dx in 0..kw {
                    let xx = base_x + dx as isize;
                    if xx < 0 || xx >= w as isize {
                        continue;
                    }
                    for ci in 0..cin {
                        let a = x.at(yy as usize, xx as usize, ci) as i32 - zp;
                        let wrow = &wq[(((dy * kw + dx) * cin) + ci) * cout..][..cout];
                        for (acc_co, &wv) in acc.iter_mut().zip(wrow) {
                            *acc_co += a * wv as i32;
                        }
                    }
                }
            }
            let orow = &mut out[(oy * ow + ox) * cout..][..cout];
            for (o, &a) in orow.iter_mut().zip(acc.iter()) {
                *o = pe::requant(a, &rq);
            }
        }
    }
    Tensor::new(Shape::new(oh, ow, cout), out)
}

fn dwconv(name: &str, x: &Tensor, stride: usize) -> Tensor {
    let (h, w, c) = (x.shape.h, x.shape.w, x.shape.c);
    let wq = weights::gen_weights_i8(&format!("{name}/w"), 9 * c);
    let bias = weights::gen_bias_i32(name, c);
    let rq = rq_for(9, true);
    let zp = quant::ZP;
    let oh = (h + 2 - 3) / stride + 1;
    let ow = (w + 2 - 3) / stride + 1;
    let mut out = vec![0u8; oh * ow * c];
    for oy in 0..oh {
        for ox in 0..ow {
            let base_y = (oy * stride) as isize - 1;
            let base_x = (ox * stride) as isize - 1;
            for ch in 0..c {
                let mut acc = bias[ch];
                for dy in 0..3 {
                    let yy = base_y + dy as isize;
                    if yy < 0 || yy >= h as isize {
                        continue;
                    }
                    for dx in 0..3 {
                        let xx = base_x + dx as isize;
                        if xx < 0 || xx >= w as isize {
                            continue;
                        }
                        // weight layout (3, 3, c)
                        acc = pe::mac(acc, x.at(yy as usize, xx as usize, ch), zp, wq[(dy * 3 + dx) * c + ch]);
                    }
                }
                out[(oy * ow + ox) * c + ch] = pe::requant(acc, &rq);
            }
        }
    }
    Tensor::new(Shape::new(oh, ow, c), out)
}

fn dense(name: &str, x: &Tensor, n_out: usize) -> Tensor {
    let k = x.shape.elems();
    let wq = weights::gen_weights_i8(&format!("{name}/w"), k * n_out);
    let bias = weights::gen_bias_i32(name, n_out);
    let rq = rq_for(k, false);
    let zp = quant::ZP;
    // co-innermost like conv: weights (k, n_out) stream row by row
    let mut acc = bias.clone();
    for (ci, &xv) in x.data.iter().enumerate() {
        let a = xv as i32 - zp;
        let wrow = &wq[ci * n_out..][..n_out];
        for (acc_co, &wv) in acc.iter_mut().zip(wrow) {
            *acc_co += a * wv as i32;
        }
    }
    let out = acc.iter().map(|&a| pe::requant(a, &rq)).collect();
    Tensor::new(Shape::new(1, 1, n_out), out)
}

fn qadd(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape, b.shape);
    let p = QAdd::default_params();
    let data = a.data.iter().zip(&b.data).map(|(&x, &y)| p.apply(x, y)).collect();
    Tensor::new(a.shape, data)
}

fn avgpool(x: &Tensor) -> Tensor {
    let (h, w, c) = (x.shape.h, x.shape.w, x.shape.c);
    let n = (h * w) as i64;
    let mut out = vec![0u8; c];
    for (ch, o) in out.iter_mut().enumerate() {
        let mut sum = 0i64;
        for y in 0..h {
            for xx in 0..w {
                sum += x.at(y, xx, ch) as i64;
            }
        }
        *o = pe::avg_round(sum, n);
    }
    Tensor::new(Shape::new(1, 1, c), out)
}

fn upsample(x: &Tensor, to_h: usize, to_w: usize) -> Tensor {
    let c = x.shape.c;
    let mut out = vec![0u8; to_h * to_w * c];
    for y in 0..to_h {
        for xx in 0..to_w {
            for ch in 0..c {
                out[(y * to_w + xx) * c + ch] = x.at(y / 2, xx / 2, ch);
            }
        }
    }
    Tensor::new(Shape::new(to_h, to_w, c), out)
}

fn nlu(x: &Tensor) -> Tensor {
    let data = x.data.iter().map(|&v| pe::nlu_sigmoid(v, quant::ZP)).collect();
    Tensor::new(x.shape, data)
}

/// Generate the deterministic synthetic input for a registry model name
/// (same stream as `aot.py`).
pub fn synthetic_input(registry_name: &str, shape: Shape) -> Tensor {
    Tensor::new(shape, weights::gen_input_u8(registry_name, shape.elems()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn tinycnn_runs_and_is_deterministic() {
        let g = models::artifact_graph("tinycnn_24x32").unwrap();
        let x = synthetic_input("tinycnn_24x32", g.input);
        let y1 = run_final(&g, &x);
        let y2 = run_final(&g, &x);
        assert_eq!(y1.data, y2.data);
        assert_eq!(y1.shape, Shape::new(1, 1, 10));
    }

    #[test]
    fn conv_padding_is_neutral() {
        // constant-zp input -> every output position sees identical taps
        let mut g = Graph::new("padtest", Shape::new(6, 6, 4));
        g.push("padtest/c", Op::Conv { kh: 3, kw: 3, cout: 8, stride: 1, relu: true }, vec![INPUT]);
        let x = Tensor::new(g.input, vec![quant::ZP as u8; 6 * 6 * 4]);
        let y = run_final(&g, &x);
        for co in 0..8 {
            let v0 = y.data[co];
            for p in 0..36 {
                assert_eq!(y.data[p * 8 + co], v0);
            }
        }
    }

    #[test]
    fn upsample_crops_to_target() {
        let mut g = Graph::new("up", Shape::new(2, 2, 3));
        g.push("up/u", Op::Upsample2x { to_h: 3, to_w: 4 }, vec![INPUT]);
        let x = synthetic_input("up", g.input);
        let y = run_final(&g, &x);
        assert_eq!(y.shape, Shape::new(3, 4, 3));
        assert_eq!(y.at(2, 3, 1), x.at(1, 1, 1));
    }

    use crate::graph::{Graph, Op, INPUT};

    #[test]
    fn residual_add_identity() {
        let mut g = Graph::new("addid", Shape::new(4, 4, 8));
        let a = g.push("addid/a", Op::Conv { kh: 1, kw: 1, cout: 8, stride: 1, relu: true }, vec![INPUT]);
        g.push("addid/add", Op::Add, vec![a, a]);
        let x = synthetic_input("addid", g.input);
        let outs = run(&g, &x);
        // avg of t with itself is t
        assert_eq!(outs[1].data, outs[0].data);
    }

    #[test]
    fn all_artifact_models_run() {
        for name in ["tinycnn_24x32", "mbv1_w25_48x64", "mbv2_w25_48x64", "fpnseg_w25_48x64"] {
            let g = models::artifact_graph(name).unwrap();
            let x = synthetic_input(name, g.input);
            let y = run_final(&g, &x);
            assert_eq!(y.shape, g.output(), "{name}");
            // non-degenerate output
            let first = y.data[0];
            assert!(y.data.iter().any(|&v| v != first), "{name} output collapsed");
        }
    }
}
