//! Functional interpreter — executes a quantized [`Graph`] with the exact
//! PE integer semantics, materializing weights from the shared PRNG
//! streams. Byte-for-byte equivalent to the JAX/Pallas golden models
//! (proven against the PJRT artifacts in `rust/tests/golden_equivalence.rs`).

use crate::graph::{Graph, Op, Shape, INPUT};
use crate::quant::{self, weights, QAdd, Requant};
use crate::sim::pe;

/// A uint8 activation tensor in HWC layout.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Shape,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn new(shape: Shape, data: Vec<u8>) -> Self {
        assert_eq!(shape.elems(), data.len());
        Tensor { shape, data }
    }

    #[inline]
    fn at(&self, y: usize, x: usize, c: usize) -> u8 {
        self.data[(y * self.shape.w + x) * self.shape.c + c]
    }
}

/// Execute the graph on an input frame; returns every layer's output
/// (the last entry is the network output).
pub fn run(g: &Graph, input: &Tensor) -> Vec<Tensor> {
    assert_eq!(input.shape, g.input, "input shape mismatch");
    let mut outs: Vec<Tensor> = Vec::with_capacity(g.layers.len());
    for l in &g.layers {
        let get = |i: usize| -> &Tensor { if i == INPUT { input } else { &outs[i] } };
        let x = get(l.inputs[0]);
        let y = match &l.op {
            Op::Conv { kh, kw, cout, stride, relu } => conv(&l.name, x, *kh, *kw, *cout, *stride, *relu),
            Op::DwConv { stride } => dwconv(&l.name, x, *stride),
            Op::Dense { out } => dense(&l.name, x, *out),
            Op::Add => qadd(x, get(l.inputs[1])),
            Op::GlobalAvgPool => avgpool(x),
            Op::Upsample2x { to_h, to_w } => upsample(x, *to_h, *to_w),
            Op::NluSigmoid => nlu(x),
        };
        debug_assert_eq!(y.shape, l.out_shape, "shape mismatch at {}", l.name);
        outs.push(y);
    }
    outs
}

/// Convenience: run and return only the final output.
pub fn run_final(g: &Graph, input: &Tensor) -> Tensor {
    run(g, input).pop().expect("empty graph")
}

fn rq_for(k: usize, relu: bool) -> Requant {
    quant::requant_for_reduction(k, relu, false)
}

fn conv(name: &str, x: &Tensor, kh: usize, kw: usize, cout: usize, stride: usize, relu: bool) -> Tensor {
    let (h, w, cin) = (x.shape.h, x.shape.w, x.shape.c);
    let k = kh * kw * cin;
    let wq = weights::gen_weights_i8(&format!("{name}/w"), k * cout);
    let bias = weights::gen_bias_i32(name, cout);
    let rq = rq_for(k, relu);
    let (ph, pw) = ((kh - 1) / 2, (kw - 1) / 2);
    let oh = (h + 2 * ph - kh) / stride + 1;
    let ow = (w + 2 * pw - kw) / stride + 1;
    let zp = quant::ZP;
    let (hi, wi) = (h as isize, w as isize);
    // contiguous HWC taps under one kernel row
    let row_taps = kw * cin;
    let mut out = vec![0u8; oh * ow * cout];
    // co-innermost accumulation: the weight layout (kh, kw, cin, cout) is
    // contiguous in co, so the inner loop streams both operands linearly —
    // the software analog of the multicast register feeding all 8 PEs of an
    // NCB the same activation while each PE owns one output channel.
    let mut acc = vec![0i32; cout];
    for oy in 0..oh {
        let base_y = (oy * stride) as isize - ph as isize;
        for ox in 0..ow {
            let base_x = (ox * stride) as isize - pw as isize;
            acc.copy_from_slice(&bias);
            let interior = base_y >= 0
                && base_y + kh as isize <= hi
                && base_x >= 0
                && base_x + kw as isize <= wi;
            if interior {
                // interior fast path: every kernel row is one contiguous
                // activation slice paired with one contiguous weight block,
                // no per-tap index arithmetic or bounds checks
                let (y0, x0) = (base_y as usize, base_x as usize);
                for dy in 0..kh {
                    let arow = &x.data[((y0 + dy) * w + x0) * cin..][..row_taps];
                    let wbase = dy * row_taps * cout;
                    for (t, &xv) in arow.iter().enumerate() {
                        let a = xv as i32 - zp;
                        let wrow = &wq[wbase + t * cout..][..cout];
                        for (acc_co, &wv) in acc.iter_mut().zip(wrow) {
                            *acc_co += a * wv as i32;
                        }
                    }
                }
            } else {
                // border path: clip padded taps (they contribute
                // (zp - zp) * w = 0), pixel slices still hoisted
                for dy in 0..kh {
                    let yy = base_y + dy as isize;
                    if yy < 0 || yy >= hi {
                        continue;
                    }
                    for dx in 0..kw {
                        let xx = base_x + dx as isize;
                        if xx < 0 || xx >= wi {
                            continue;
                        }
                        let apx = &x.data[((yy as usize) * w + xx as usize) * cin..][..cin];
                        let wbase = (dy * kw + dx) * cin * cout;
                        for (ci, &xv) in apx.iter().enumerate() {
                            let a = xv as i32 - zp;
                            let wrow = &wq[wbase + ci * cout..][..cout];
                            for (acc_co, &wv) in acc.iter_mut().zip(wrow) {
                                *acc_co += a * wv as i32;
                            }
                        }
                    }
                }
            }
            let orow = &mut out[(oy * ow + ox) * cout..][..cout];
            for (o, &a) in orow.iter_mut().zip(acc.iter()) {
                *o = pe::requant(a, &rq);
            }
        }
    }
    Tensor::new(Shape::new(oh, ow, cout), out)
}

fn dwconv(name: &str, x: &Tensor, stride: usize) -> Tensor {
    let (h, w, c) = (x.shape.h, x.shape.w, x.shape.c);
    let wq = weights::gen_weights_i8(&format!("{name}/w"), 9 * c);
    let bias = weights::gen_bias_i32(name, c);
    let rq = rq_for(9, true);
    let zp = quant::ZP;
    let oh = (h + 2 - 3) / stride + 1;
    let ow = (w + 2 - 3) / stride + 1;
    let (hi, wi) = (h as isize, w as isize);
    let mut out = vec![0u8; oh * ow * c];
    // channel-vector accumulation: per tap, activations and weights (layout
    // (3, 3, c)) are both length-c contiguous slices — all channels advance
    // in lockstep, the SIMD-lane view of an NCB
    let mut acc = vec![0i32; c];
    for oy in 0..oh {
        let base_y = (oy * stride) as isize - 1;
        for ox in 0..ow {
            let base_x = (ox * stride) as isize - 1;
            acc.copy_from_slice(&bias);
            let interior = base_y >= 0 && base_y + 3 <= hi && base_x >= 0 && base_x + 3 <= wi;
            if interior {
                let (y0, x0) = (base_y as usize, base_x as usize);
                for dy in 0..3 {
                    for dx in 0..3 {
                        let apx = &x.data[((y0 + dy) * w + x0 + dx) * c..][..c];
                        let wpx = &wq[(dy * 3 + dx) * c..][..c];
                        for ((acc_ch, &xv), &wv) in acc.iter_mut().zip(apx).zip(wpx) {
                            *acc_ch = pe::mac(*acc_ch, xv, zp, wv);
                        }
                    }
                }
            } else {
                for dy in 0..3usize {
                    let yy = base_y + dy as isize;
                    if yy < 0 || yy >= hi {
                        continue;
                    }
                    for dx in 0..3usize {
                        let xx = base_x + dx as isize;
                        if xx < 0 || xx >= wi {
                            continue;
                        }
                        let apx = &x.data[((yy as usize) * w + xx as usize) * c..][..c];
                        let wpx = &wq[(dy * 3 + dx) * c..][..c];
                        for ((acc_ch, &xv), &wv) in acc.iter_mut().zip(apx).zip(wpx) {
                            *acc_ch = pe::mac(*acc_ch, xv, zp, wv);
                        }
                    }
                }
            }
            let orow = &mut out[(oy * ow + ox) * c..][..c];
            for (o, &a) in orow.iter_mut().zip(acc.iter()) {
                *o = pe::requant(a, &rq);
            }
        }
    }
    Tensor::new(Shape::new(oh, ow, c), out)
}

fn dense(name: &str, x: &Tensor, n_out: usize) -> Tensor {
    let k = x.shape.elems();
    let wq = weights::gen_weights_i8(&format!("{name}/w"), k * n_out);
    let bias = weights::gen_bias_i32(name, n_out);
    let rq = rq_for(k, false);
    let zp = quant::ZP;
    // co-innermost like conv: weights (k, n_out) stream row by row
    let mut acc = bias.clone();
    for (ci, &xv) in x.data.iter().enumerate() {
        let a = xv as i32 - zp;
        let wrow = &wq[ci * n_out..][..n_out];
        for (acc_co, &wv) in acc.iter_mut().zip(wrow) {
            *acc_co += a * wv as i32;
        }
    }
    let out = acc.iter().map(|&a| pe::requant(a, &rq)).collect();
    Tensor::new(Shape::new(1, 1, n_out), out)
}

fn qadd(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape, b.shape);
    let p = QAdd::default_params();
    let data = a.data.iter().zip(&b.data).map(|(&x, &y)| p.apply(x, y)).collect();
    Tensor::new(a.shape, data)
}

fn avgpool(x: &Tensor) -> Tensor {
    let (h, w, c) = (x.shape.h, x.shape.w, x.shape.c);
    let n = (h * w) as i64;
    // single pass over the HWC data: i64 sums are exact, so the per-channel
    // result is order-independent
    let mut sums = vec![0i64; c];
    for px in x.data.chunks_exact(c) {
        for (s, &v) in sums.iter_mut().zip(px) {
            *s += v as i64;
        }
    }
    let out = sums.iter().map(|&s| pe::avg_round(s, n)).collect();
    Tensor::new(Shape::new(1, 1, c), out)
}

fn upsample(x: &Tensor, to_h: usize, to_w: usize) -> Tensor {
    let (w, c) = (x.shape.w, x.shape.c);
    let mut out = vec![0u8; to_h * to_w * c];
    for (y, orow) in out.chunks_exact_mut(to_w * c).enumerate() {
        let srow = &x.data[(y / 2) * w * c..];
        for (xx, opx) in orow.chunks_exact_mut(c).enumerate() {
            opx.copy_from_slice(&srow[(xx / 2) * c..][..c]);
        }
    }
    Tensor::new(Shape::new(to_h, to_w, c), out)
}

fn nlu(x: &Tensor) -> Tensor {
    let data = x.data.iter().map(|&v| pe::nlu_sigmoid(v, quant::ZP)).collect();
    Tensor::new(x.shape, data)
}

/// Generate the deterministic synthetic input for a registry model name
/// (same stream as `aot.py`).
pub fn synthetic_input(registry_name: &str, shape: Shape) -> Tensor {
    Tensor::new(shape, weights::gen_input_u8(registry_name, shape.elems()))
}

/// Naive reference kernels — the original `Tensor::at`-indexed loops, kept
/// verbatim as the oracle the row-sliced fast kernels are proven against
/// (see `kernel_equivalence` tests below).
#[cfg(test)]
pub(crate) mod reference {
    use super::*;

    pub fn conv_naive(
        name: &str,
        x: &Tensor,
        kh: usize,
        kw: usize,
        cout: usize,
        stride: usize,
        relu: bool,
    ) -> Tensor {
        let (h, w, cin) = (x.shape.h, x.shape.w, x.shape.c);
        let k = kh * kw * cin;
        let wq = weights::gen_weights_i8(&format!("{name}/w"), k * cout);
        let bias = weights::gen_bias_i32(name, cout);
        let rq = rq_for(k, relu);
        let (ph, pw) = ((kh - 1) / 2, (kw - 1) / 2);
        let oh = (h + 2 * ph - kh) / stride + 1;
        let ow = (w + 2 * pw - kw) / stride + 1;
        let zp = quant::ZP;
        let mut out = vec![0u8; oh * ow * cout];
        let mut acc = vec![0i32; cout];
        for oy in 0..oh {
            for ox in 0..ow {
                let base_y = (oy * stride) as isize - ph as isize;
                let base_x = (ox * stride) as isize - pw as isize;
                acc.copy_from_slice(&bias);
                for dy in 0..kh {
                    let yy = base_y + dy as isize;
                    if yy < 0 || yy >= h as isize {
                        continue;
                    }
                    for dx in 0..kw {
                        let xx = base_x + dx as isize;
                        if xx < 0 || xx >= w as isize {
                            continue;
                        }
                        for ci in 0..cin {
                            let a = x.at(yy as usize, xx as usize, ci) as i32 - zp;
                            let wrow = &wq[(((dy * kw + dx) * cin) + ci) * cout..][..cout];
                            for (acc_co, &wv) in acc.iter_mut().zip(wrow) {
                                *acc_co += a * wv as i32;
                            }
                        }
                    }
                }
                let orow = &mut out[(oy * ow + ox) * cout..][..cout];
                for (o, &a) in orow.iter_mut().zip(acc.iter()) {
                    *o = pe::requant(a, &rq);
                }
            }
        }
        Tensor::new(Shape::new(oh, ow, cout), out)
    }

    pub fn dwconv_naive(name: &str, x: &Tensor, stride: usize) -> Tensor {
        let (h, w, c) = (x.shape.h, x.shape.w, x.shape.c);
        let wq = weights::gen_weights_i8(&format!("{name}/w"), 9 * c);
        let bias = weights::gen_bias_i32(name, c);
        let rq = rq_for(9, true);
        let zp = quant::ZP;
        let oh = (h + 2 - 3) / stride + 1;
        let ow = (w + 2 - 3) / stride + 1;
        let mut out = vec![0u8; oh * ow * c];
        for oy in 0..oh {
            for ox in 0..ow {
                let base_y = (oy * stride) as isize - 1;
                let base_x = (ox * stride) as isize - 1;
                for ch in 0..c {
                    let mut acc = bias[ch];
                    for dy in 0..3usize {
                        let yy = base_y + dy as isize;
                        if yy < 0 || yy >= h as isize {
                            continue;
                        }
                        for dx in 0..3usize {
                            let xx = base_x + dx as isize;
                            if xx < 0 || xx >= w as isize {
                                continue;
                            }
                            let wv = wq[(dy * 3 + dx) * c + ch];
                            acc = pe::mac(acc, x.at(yy as usize, xx as usize, ch), zp, wv);
                        }
                    }
                    out[(oy * ow + ox) * c + ch] = pe::requant(acc, &rq);
                }
            }
        }
        Tensor::new(Shape::new(oh, ow, c), out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn tinycnn_runs_and_is_deterministic() {
        let g = models::artifact_graph("tinycnn_24x32").unwrap();
        let x = synthetic_input("tinycnn_24x32", g.input);
        let y1 = run_final(&g, &x);
        let y2 = run_final(&g, &x);
        assert_eq!(y1.data, y2.data);
        assert_eq!(y1.shape, Shape::new(1, 1, 10));
    }

    #[test]
    fn conv_padding_is_neutral() {
        // constant-zp input -> every output position sees identical taps
        let mut g = Graph::new("padtest", Shape::new(6, 6, 4));
        g.push("padtest/c", Op::Conv { kh: 3, kw: 3, cout: 8, stride: 1, relu: true }, vec![INPUT]);
        let x = Tensor::new(g.input, vec![quant::ZP as u8; 6 * 6 * 4]);
        let y = run_final(&g, &x);
        for co in 0..8 {
            let v0 = y.data[co];
            for p in 0..36 {
                assert_eq!(y.data[p * 8 + co], v0);
            }
        }
    }

    #[test]
    fn upsample_crops_to_target() {
        let mut g = Graph::new("up", Shape::new(2, 2, 3));
        g.push("up/u", Op::Upsample2x { to_h: 3, to_w: 4 }, vec![INPUT]);
        let x = synthetic_input("up", g.input);
        let y = run_final(&g, &x);
        assert_eq!(y.shape, Shape::new(3, 4, 3));
        assert_eq!(y.at(2, 3, 1), x.at(1, 1, 1));
    }

    use crate::graph::{Graph, Op, INPUT};

    #[test]
    fn residual_add_identity() {
        let mut g = Graph::new("addid", Shape::new(4, 4, 8));
        let a = g.push("addid/a", Op::Conv { kh: 1, kw: 1, cout: 8, stride: 1, relu: true }, vec![INPUT]);
        g.push("addid/add", Op::Add, vec![a, a]);
        let x = synthetic_input("addid", g.input);
        let outs = run(&g, &x);
        // avg of t with itself is t
        assert_eq!(outs[1].data, outs[0].data);
    }

    #[test]
    fn all_artifact_models_run() {
        for name in ["tinycnn_24x32", "mbv1_w25_48x64", "mbv2_w25_48x64", "fpnseg_w25_48x64"] {
            let g = models::artifact_graph(name).unwrap();
            let x = synthetic_input(name, g.input);
            let y = run_final(&g, &x);
            assert_eq!(y.shape, g.output(), "{name}");
            // non-degenerate output
            let first = y.data[0];
            assert!(y.data.iter().any(|&v| v != first), "{name} output collapsed");
        }
    }

    /// The fast row-sliced kernels must match the naive reference
    /// element-for-element on every conv/dwconv layer of every registry
    /// model, fed the true intermediate activations.
    #[test]
    fn kernel_equivalence_on_registry_models() {
        for name in ["tinycnn_24x32", "mbv1_w25_48x64", "mbv2_w25_48x64", "fpnseg_w25_48x64"] {
            let g = models::artifact_graph(name).unwrap();
            let input = synthetic_input(name, g.input);
            let outs = run(&g, &input);
            for (li, l) in g.layers.iter().enumerate() {
                let x = if l.inputs[0] == INPUT { &input } else { &outs[l.inputs[0]] };
                match &l.op {
                    Op::Conv { kh, kw, cout, stride, relu } => {
                        let naive =
                            reference::conv_naive(&l.name, x, *kh, *kw, *cout, *stride, *relu);
                        assert_eq!(naive.shape, outs[li].shape, "{name}/{}", l.name);
                        assert_eq!(naive.data, outs[li].data, "{name}/{}", l.name);
                    }
                    Op::DwConv { stride } => {
                        let naive = reference::dwconv_naive(&l.name, x, *stride);
                        assert_eq!(naive.data, outs[li].data, "{name}/{}", l.name);
                    }
                    _ => {}
                }
            }
        }
    }

    fn xorshift(state: &mut u64) -> u64 {
        *state ^= *state << 13;
        *state ^= *state >> 7;
        *state ^= *state << 17;
        *state
    }

    /// Randomized shapes — odd extents, stride 2, 1x1/3x3/5x5 and
    /// rectangular kernels — the cases the interior/border split must get
    /// right. Deterministic xorshift keeps the sweep reproducible.
    #[test]
    fn kernel_equivalence_on_random_shapes() {
        let mut st = 0x9E37_79B9_7F4A_7C15u64;
        for case in 0..24 {
            let h = 3 + (xorshift(&mut st) % 10) as usize;
            let w = 3 + (xorshift(&mut st) % 10) as usize;
            let cin = 1 + (xorshift(&mut st) % 7) as usize;
            let cout = 1 + (xorshift(&mut st) % 8) as usize;
            let kh = [1, 3, 5][(xorshift(&mut st) % 3) as usize];
            let kw = [1, 3, 5][(xorshift(&mut st) % 3) as usize];
            let stride = 1 + (xorshift(&mut st) % 2) as usize;
            let relu = xorshift(&mut st) % 2 == 0;
            let shape = Shape::new(h, w, cin);
            let x = Tensor::new(
                shape,
                weights::gen_input_u8(&format!("kern{case}/in"), shape.elems()),
            );
            let tag = format!("case {case}: {h}x{w}x{cin} k{kh}x{kw} s{stride} cout{cout}");
            let name = format!("kern{case}/conv");
            let fast = conv(&name, &x, kh, kw, cout, stride, relu);
            let naive = reference::conv_naive(&name, &x, kh, kw, cout, stride, relu);
            assert_eq!(fast.shape, naive.shape, "{tag}");
            assert_eq!(fast.data, naive.data, "{tag}");
            // depthwise over the same frame
            let dname = format!("kern{case}/dw");
            let dfast = dwconv(&dname, &x, stride);
            let dnaive = reference::dwconv_naive(&dname, &x, stride);
            assert_eq!(dfast.shape, dnaive.shape, "{tag} dw");
            assert_eq!(dfast.data, dnaive.data, "{tag} dw");
        }
    }
}
