//! Global L2 memory — 5 MB in 16 blocks of 64-bit words, split 3 MB on the
//! bottom die / 2 MB on the middle die, joined by 2048 data TSVs
//! (1024 bits each way), as §IV-A describes.
//!
//! Functional storage + the address-map/partition logic the placement
//! stage and the DMPA column transfers rely on, with per-partition and
//! per-block traffic accounting for the energy model.

use crate::config::ArchConfig;
use crate::isa::Space;

/// The unified L2 address space of the system.
#[derive(Debug)]
pub struct L2Memory {
    bottom_bytes: usize,
    data: Vec<u8>,
    blocks: usize,
    /// read+write bytes per block (energy/contention accounting)
    traffic: Vec<u64>,
    /// bytes that crossed the TSVs (middle-partition accesses)
    pub tsv_bytes: u64,
    /// DMPA beats that hit a block more than once per word slot
    /// (the PMU's `l2_bank` stall reason at the functional level)
    conflict_beats: u64,
}

impl L2Memory {
    pub fn new(cfg: &ArchConfig) -> Self {
        L2Memory {
            bottom_bytes: cfg.l2_bottom_bytes,
            data: vec![0; cfg.l2_bytes()],
            blocks: cfg.l2_blocks,
            traffic: vec![0; cfg.l2_blocks],
            tsv_bytes: 0,
            conflict_beats: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.data.len()
    }

    /// Which die partition an address belongs to.
    pub fn space_of(&self, addr: usize) -> Space {
        if addr < self.bottom_bytes { Space::L2Bottom } else { Space::L2Middle }
    }

    /// Which of the 16 interleaved memory blocks serves this address.
    /// Blocks are 64-bit-word interleaved inside each partition so a
    /// 1024-bit DMPA beat touches every block of a partition exactly once.
    pub fn block_of(&self, addr: usize) -> usize {
        (addr / 8) % self.blocks
    }

    fn account(&mut self, addr: usize, len: usize) {
        for i in (0..len).step_by(8) {
            let a = addr + i;
            let b = self.block_of(a);
            self.traffic[b] += 8.min(len - i) as u64;
        }
        // TSV crossing for the middle partition share
        let end = addr + len;
        if end > self.bottom_bytes {
            let start_mid = addr.max(self.bottom_bytes);
            self.tsv_bytes += (end - start_mid) as u64;
        }
    }

    pub fn write(&mut self, addr: usize, bytes: &[u8]) -> crate::Result<()> {
        anyhow::ensure!(addr + bytes.len() <= self.data.len(), "L2 write OOB: {addr}+{}", bytes.len());
        self.account(addr, bytes.len());
        self.data[addr..addr + bytes.len()].copy_from_slice(bytes);
        Ok(())
    }

    pub fn read(&mut self, addr: usize, len: usize) -> crate::Result<Vec<u8>> {
        anyhow::ensure!(addr + len <= self.data.len(), "L2 read OOB: {addr}+{len}");
        self.account(addr, len);
        Ok(self.data[addr..addr + len].to_vec())
    }

    /// A full-width DMPA beat (128 bytes) is conflict-free iff its block
    /// footprint covers each block at most once per 64-bit word slot.
    pub fn dmpa_beat_conflict_free(&self, addr: usize) -> bool {
        // aligned 128-byte beats touch blocks 0..16 exactly once each
        addr % 8 == 0
    }

    /// Account a DMPA column stream of `len` bytes starting at `addr`:
    /// returns the number of conflicted beats and accumulates them into the
    /// cumulative [`Self::conflict_beats`] counter. Unaligned streams pay a
    /// block-port collision on every 128-byte beat — the functional-model
    /// counterpart of the cycle engine's `l2_bank` PMU stall reason.
    pub fn account_dmpa_stream(&mut self, addr: usize, len: usize) -> u64 {
        let beats = (len as u64).div_ceil(128);
        let conflicts = if self.dmpa_beat_conflict_free(addr) { 0 } else { beats };
        self.conflict_beats += conflicts;
        conflicts
    }

    /// Cumulative conflicted DMPA beats across every accounted stream.
    pub fn conflict_beats(&self) -> u64 {
        self.conflict_beats
    }

    pub fn traffic(&self) -> &[u64] {
        &self.traffic
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l2() -> L2Memory {
        L2Memory::new(&ArchConfig::j3dai())
    }

    #[test]
    fn capacity_and_partition_map() {
        let m = l2();
        assert_eq!(m.capacity(), 5 * 1024 * 1024);
        assert_eq!(m.space_of(0), Space::L2Bottom);
        assert_eq!(m.space_of(3 * 1024 * 1024 - 1), Space::L2Bottom);
        assert_eq!(m.space_of(3 * 1024 * 1024), Space::L2Middle);
    }

    #[test]
    fn read_write_roundtrip() {
        let mut m = l2();
        m.write(1234, &[9, 8, 7, 6]).unwrap();
        assert_eq!(m.read(1234, 4).unwrap(), vec![9, 8, 7, 6]);
        assert!(m.write(5 * 1024 * 1024 - 1, &[0, 0]).is_err());
        assert!(m.read(5 * 1024 * 1024, 1).is_err());
    }

    #[test]
    fn blocks_interleave_by_word() {
        let m = l2();
        assert_eq!(m.block_of(0), 0);
        assert_eq!(m.block_of(8), 1);
        assert_eq!(m.block_of(8 * 15), 15);
        assert_eq!(m.block_of(8 * 16), 0);
        // a 128-byte aligned beat covers all 16 blocks exactly once
        let mut seen = vec![0; 16];
        for i in (0..128).step_by(8) {
            seen[m.block_of(i)] += 1;
        }
        assert!(seen.iter().all(|&c| c == 1));
        assert!(m.dmpa_beat_conflict_free(0));
        assert!(!m.dmpa_beat_conflict_free(3));
    }

    #[test]
    fn tsv_accounting_only_for_middle_partition() {
        let mut m = l2();
        m.write(0, &[0u8; 256]).unwrap();
        assert_eq!(m.tsv_bytes, 0);
        let mid = 3 * 1024 * 1024;
        m.write(mid, &[0u8; 100]).unwrap();
        assert_eq!(m.tsv_bytes, 100);
        // straddling write counts only the middle share
        m.write(mid - 10, &[0u8; 30]).unwrap();
        assert_eq!(m.tsv_bytes, 120);
    }

    #[test]
    fn dmpa_streams_count_conflicted_beats() {
        let mut m = l2();
        // aligned stream: zero conflicts regardless of length
        assert_eq!(m.account_dmpa_stream(0, 1024), 0);
        assert_eq!(m.conflict_beats(), 0);
        // unaligned stream: every 128-byte beat conflicts (300 B -> 3 beats)
        assert_eq!(m.account_dmpa_stream(3, 300), 3);
        assert_eq!(m.conflict_beats(), 3);
        // a second unaligned stream accumulates
        assert_eq!(m.account_dmpa_stream(9, 128), 1);
        assert_eq!(m.conflict_beats(), 4);
    }

    #[test]
    fn traffic_spreads_over_blocks() {
        let mut m = l2();
        m.write(0, &vec![1u8; 1024]).unwrap();
        let t = m.traffic();
        assert!(t.iter().all(|&b| b == 64), "{t:?}"); // 1024/16 per block
    }

    #[test]
    fn two_networks_fit_simultaneously() {
        // §IV-A: 5 MB "enables the execution of several networks that
        // require multiple MBs to store parameters" — MBv1(a=1) + MBv2(a=1)
        // int8 parameters do NOT both fit (4.3 + 3.5 MB), but MBv2 + the
        // segmentation net do; verify with real placement sums.
        let mbv2 = crate::models::paper_mbv2().total_param_bytes();
        let seg = crate::models::paper_seg().total_param_bytes();
        let m = l2();
        assert!(mbv2 + seg <= m.capacity() as u64, "mbv2={mbv2} seg={seg}");
    }
}
