//! System-level simulation: compile a graph, run every cluster program,
//! merge the activity, add host orchestration and DMA-bus contention —
//! producing the numbers Table I/II report.
//!
//! Cluster programs are independent once compiled, so the per-cluster
//! cycle simulations can run on host threads (`*_threads` entry points).
//! Results are merged in cluster-index order, which keeps every artifact
//! — `SimResult`, PMU banks, trace spans, folded profiles — byte-for-byte
//! identical to the serial path (see `tests/perf_parallel.rs`).

use super::engine::{run_cluster, run_cluster_traced, ClusterRun, InstrSpan};
use crate::compiler::{self, scheduler, Compiled};
use crate::config::ArchConfig;
use crate::graph::Graph;
use crate::isa::{Engine, Program};
use crate::power::{self, Activity, EnergyModel};
use crate::telemetry::pmu::N_STALL_REASONS;
use crate::telemetry::{
    energy, ArgValue, FoldedProfile, RingSampler, StallReason, TraceBuilder, SIM_PID,
};

/// Full result of simulating one inference.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub model: String,
    pub total_macs: u64,
    /// End-to-end cycles (slowest cluster + serial host sections).
    pub cycles: u64,
    pub activity: Activity,
    /// Latency at the configured clock, ms.
    pub latency_ms: f64,
    /// MAC/cycle efficiency (Table I/II metric).
    pub mac_efficiency: f64,
    /// Program footprint across clusters, bytes.
    pub program_bytes: usize,
    /// Host cycles (serial orchestration share).
    pub host_cycles: u64,
    /// Maximum sustainable frame rate.
    pub max_fps: f64,
    /// Per-cluster runs with their PMU banks. System-level `HostSync`
    /// cycles (waiting on the slowest cluster + host tail) are folded into
    /// each cluster's **total** bank, so per cluster
    /// `pmu.total.accounted() == cycles`; per-layer banks keep only the
    /// engine-level reasons (no layer owns the post-halt wait).
    pub clusters: Vec<ClusterRun>,
}

impl SimResult {
    /// Power at a frame rate using an energy model (None if the frame rate
    /// exceeds what the latency allows — the paper prints "-" there).
    pub fn power_mw(&self, em: &EnergyModel, fps: f64) -> Option<f64> {
        if fps > self.max_fps {
            return None;
        }
        Some(em.power_mw(&self.activity, fps))
    }

    /// TOPs/W at a frame rate (Table I "Power efficiency").
    pub fn tops_per_watt(&self, em: &EnergyModel, fps: f64) -> Option<f64> {
        if fps > self.max_fps {
            return None;
        }
        Some(em.tops_per_watt(&self.activity, fps))
    }
}

/// Simulate one inference of `g` on `cfg`.
pub fn simulate(g: &Graph, cfg: &ArchConfig) -> crate::Result<SimResult> {
    simulate_threads(g, cfg, 1)
}

/// [`simulate`] with the per-cluster simulations spread across up to
/// `threads` host threads.
pub fn simulate_threads(g: &Graph, cfg: &ArchConfig, threads: usize) -> crate::Result<SimResult> {
    let compiled = compiler::compile(g, cfg)?;
    Ok(simulate_compiled_threads(g, cfg, &compiled, threads))
}

/// Default worker-thread count for cluster-parallel simulation (the CLI
/// `--threads` default): the host's available parallelism, or 1 if it
/// cannot be determined.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(std::num::NonZeroUsize::get).unwrap_or(1)
}

/// Run `f` over every cluster program on up to `threads` scoped workers,
/// returning results in program order. Each worker owns a disjoint
/// contiguous range of result slots, so the merge order — and therefore
/// every downstream artifact — is independent of thread scheduling;
/// `run_cluster` itself is a pure function of `(cfg, program, penalty)`.
fn run_partitioned<T, F>(programs: &[Program], threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(&Program) -> T + Sync,
{
    let n = programs.len();
    let workers = threads.clamp(1, n.max(1));
    if workers <= 1 {
        return programs.iter().map(f).collect();
    }
    let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    let chunk = n.div_ceil(workers);
    let fref = &f;
    std::thread::scope(|s| {
        for (slot_chunk, prog_chunk) in slots.chunks_mut(chunk).zip(programs.chunks(chunk)) {
            s.spawn(move || {
                for (slot, p) in slot_chunk.iter_mut().zip(prog_chunk) {
                    *slot = Some(fref(p));
                }
            });
        }
    });
    slots.into_iter().map(|o| o.expect("every cluster slot filled")).collect()
}

/// DMA-bus contention: the 64-bit system interconnect is shared by all
/// clusters; when the DMPA is disabled every cluster's DMA traffic
/// serializes, modeled as a cycle multiplier equal to the cluster count.
fn dma_penalty(cfg: &ArchConfig) -> u64 {
    if cfg.dmpa_enabled {
        1
    } else {
        cfg.clusters as u64
    }
}

/// Simulate from an already-compiled artifact (reused by the coordinator).
pub fn simulate_compiled(g: &Graph, cfg: &ArchConfig, compiled: &Compiled) -> SimResult {
    simulate_compiled_threads(g, cfg, compiled, 1)
}

/// [`simulate_compiled`] with the per-cluster simulations spread across up
/// to `threads` host threads. Bit-identical to the serial path for any
/// thread count.
pub fn simulate_compiled_threads(
    g: &Graph,
    cfg: &ArchConfig,
    compiled: &Compiled,
    threads: usize,
) -> SimResult {
    let penalty = dma_penalty(cfg);
    let runs: Vec<ClusterRun> =
        run_partitioned(&compiled.cluster_programs, threads, |p| run_cluster(cfg, p, penalty));
    finish(g, cfg, compiled, &runs)
}

/// Merge per-cluster runs into the system-level result.
fn finish(g: &Graph, cfg: &ArchConfig, compiled: &Compiled, runs: &[ClusterRun]) -> SimResult {
    // clusters run concurrently: event counts add, the critical path is
    // the slowest cluster (then the serial host tail extends it)
    let mut activity = Activity::default();
    for run in runs {
        activity.merge_parallel(&run.activity);
    }
    let slowest = activity.cycles;
    let host_cycles = scheduler::host_total_cycles(&compiled.host_steps);
    let cycles = slowest + host_cycles;
    activity.cycles = cycles;

    // fold the system-level wait into each cluster's PMU total: a cluster
    // that halts early idles until the slowest cluster and the serial host
    // tail finish — after this, every cluster accounts for all `cycles`
    let mut clusters = runs.to_vec();
    for c in &mut clusters {
        c.pmu.total.stall(StallReason::HostSync, cycles - c.cycles);
    }

    SimResult {
        model: g.name.clone(),
        total_macs: g.total_macs(),
        cycles,
        latency_ms: power::latency_ms(cfg, cycles),
        mac_efficiency: activity.macs as f64 / (cycles as f64 * cfg.macs_per_cycle() as f64),
        program_bytes: compiled.program_bytes(),
        host_cycles,
        max_fps: power::max_fps(cfg, cycles),
        activity,
        clusters,
    }
}

/// Per-layer cycle/byte/MAC breakdown, aggregated from instruction spans
/// across every cluster (the `j3dai trace` table and `BENCH_telemetry.json`
/// both read this).
#[derive(Debug, Clone)]
pub struct LayerStats {
    /// Graph layer index.
    pub layer: usize,
    pub name: String,
    /// Layer extent in cluster cycles (latest span end − earliest start
    /// across all clusters).
    pub cycles: u64,
    /// Compute-engine busy cycles summed over clusters.
    pub compute_busy: u64,
    /// Transfer-engine busy cycles summed over clusters.
    pub xfer_busy: u64,
    /// Per-cluster extent minus the busier engine, summed — cycles neither
    /// engine could hide behind the other.
    pub stall_cycles: u64,
    /// PMU classification of this layer's compute-wait cycles, summed over
    /// clusters, indexed by `StallReason::index()`. A different measure
    /// than `stall_cycles` (extent-based): the PMU counts cycles the
    /// compute engine sat waiting on a classified transfer.
    pub stall_breakdown: [u64; N_STALL_REASONS],
    pub macs: u64,
    /// Bytes moved by transfer instructions.
    pub bytes: u64,
    /// `macs / (cycles * chip MAC lanes)` — the Table I metric, per layer.
    pub mac_efficiency: f64,
    /// Event-count profile of this layer, summed over all of its spans
    /// (`cycles` is the layer extent, `busy_cluster_cycles` the
    /// compute-engine occupancy — see `telemetry::energy`).
    pub activity: Activity,
    /// Modeled dynamic energy of the layer, millijoules.
    pub energy_mj: f64,
    /// Arithmetic intensity: MACs per off-cluster (DMPA + DMA) byte.
    pub arith_intensity: f64,
    /// Achieved throughput across the layer extent, GOPS (1 MAC = 2 ops).
    pub achieved_gops: f64,
}

/// Trace output of one simulated inference: the per-layer table plus a
/// [`TraceBuilder`] holding instruction, layer and host spans on simulated
/// time (pid [`SIM_PID`]).
#[derive(Debug, Clone)]
pub struct SimTrace {
    pub model: String,
    /// Cycle→time conversion used for the span timestamps.
    pub clock_ns: f64,
    pub layers: Vec<LayerStats>,
    pub trace: TraceBuilder,
    /// Folded `layer;cluster/engine;instruction` stacks (cycle weights)
    /// for flamegraph tooling (`--profile-out`).
    pub folded: FoldedProfile,
}

/// [`simulate`], also producing per-layer stats and a Perfetto-loadable
/// span trace.
pub fn simulate_traced(g: &Graph, cfg: &ArchConfig) -> crate::Result<(SimResult, SimTrace)> {
    simulate_traced_threads(g, cfg, 1)
}

/// [`simulate_traced`] with the per-cluster simulations spread across up
/// to `threads` host threads.
pub fn simulate_traced_threads(
    g: &Graph,
    cfg: &ArchConfig,
    threads: usize,
) -> crate::Result<(SimResult, SimTrace)> {
    let compiled = compiler::compile(g, cfg)?;
    Ok(simulate_compiled_traced_threads(g, cfg, &compiled, threads))
}

/// [`simulate_compiled`] with span collection. The `SimResult` matches the
/// untraced path exactly.
pub fn simulate_compiled_traced(
    g: &Graph,
    cfg: &ArchConfig,
    compiled: &Compiled,
) -> (SimResult, SimTrace) {
    simulate_compiled_traced_threads(g, cfg, compiled, 1)
}

/// [`simulate_compiled_traced`] across up to `threads` host threads. Span
/// vectors stay keyed by cluster index, so the trace and folded profile
/// are byte-identical to the serial path.
pub fn simulate_compiled_traced_threads(
    g: &Graph,
    cfg: &ArchConfig,
    compiled: &Compiled,
    threads: usize,
) -> (SimResult, SimTrace) {
    let penalty = dma_penalty(cfg);
    let results = run_partitioned(&compiled.cluster_programs, threads, |p| {
        run_cluster_traced(cfg, p, penalty)
    });
    let (runs, cluster_spans): (Vec<ClusterRun>, Vec<Vec<InstrSpan>>) = results.into_iter().unzip();
    let result = finish(g, cfg, compiled, &runs);
    let trace = build_sim_trace(g, cfg, compiled, &runs, &cluster_spans);
    (result, trace)
}

fn layer_name(g: &Graph, id: u32) -> &str {
    g.layers.get(id as usize).map(|l| l.name.as_str()).unwrap_or("setup")
}

fn build_sim_trace(
    g: &Graph,
    cfg: &ArchConfig,
    compiled: &Compiled,
    runs: &[ClusterRun],
    cluster_spans: &[Vec<InstrSpan>],
) -> SimTrace {
    let clock_ns = cfg.clock_ns();
    let us = |cyc: u64| cyc as f64 * clock_ns / 1000.0;
    // energy attribution for span args / layer stats, at the configured
    // supply voltage (identity scaling at the paper's 0.85 V point)
    let em = EnergyModel::fdsoi28().at_voltage(cfg.voltage, 0.85);
    let nclusters = cluster_spans.len() as u32;
    let layers_tid = nclusters * 2;
    let host_tid = nclusters * 2 + 1;

    let mut tb = TraceBuilder::new();
    tb.name_process(SIM_PID, &format!("sim:{}", g.name));
    for ci in 0..cluster_spans.len() {
        tb.name_thread(SIM_PID, ci as u32 * 2, &format!("cluster{ci}/COMPUTE"));
        tb.name_thread(SIM_PID, ci as u32 * 2 + 1, &format!("cluster{ci}/XFER"));
    }
    tb.name_thread(SIM_PID, layers_tid, "layers");
    tb.name_thread(SIM_PID, host_tid, "host");

    // instruction spans, one track pair per cluster; the same walk feeds
    // the folded flamegraph stacks
    let mut folded = FoldedProfile::new();
    for (ci, spans) in cluster_spans.iter().enumerate() {
        for s in spans {
            let eng = if s.engine == Engine::Xfer { "XFER" } else { "COMPUTE" };
            folded.add(
                format!("{};cluster{ci}/{eng};{}", layer_name(g, s.layer), s.label),
                s.end - s.start,
            );
            let tid = ci as u32 * 2 + u32::from(s.engine == Engine::Xfer);
            let mut args = vec![
                ("energy_pj".to_string(), ArgValue::F64(energy::span_energy_pj(&em, &s.activity))),
                ("layer".to_string(), ArgValue::U64(s.layer as u64)),
            ];
            if s.bytes > 0 {
                args.push(("bytes".to_string(), ArgValue::U64(s.bytes)));
            }
            if s.macs > 0 {
                args.push(("macs".to_string(), ArgValue::U64(s.macs)));
            }
            tb.span(
                SIM_PID,
                tid,
                s.label,
                layer_name(g, s.layer),
                us(s.start),
                us(s.end - s.start),
                args,
            );
        }
    }

    // per-layer aggregation + one span per layer on the "layers" track
    let mut layers = Vec::with_capacity(g.layers.len());
    for (li, layer) in g.layers.iter().enumerate() {
        let mut start = u64::MAX;
        let mut end = 0u64;
        let (mut comp, mut xfer, mut stall, mut macs, mut bytes) = (0u64, 0, 0, 0, 0);
        let mut layer_act = Activity::default();
        for spans in cluster_spans {
            let (mut c_start, mut c_end) = (u64::MAX, 0u64);
            let (mut c_comp, mut c_xfer) = (0u64, 0u64);
            for s in spans.iter().filter(|s| s.layer as usize == li) {
                c_start = c_start.min(s.start);
                c_end = c_end.max(s.end);
                match s.engine {
                    Engine::Xfer => c_xfer += s.end - s.start,
                    _ => c_comp += s.end - s.start,
                }
                macs += s.macs;
                bytes += s.bytes;
                layer_act.merge_sequential(&s.activity);
            }
            if c_end == 0 {
                continue; // layer has no work on this cluster
            }
            start = start.min(c_start);
            end = end.max(c_end);
            comp += c_comp;
            xfer += c_xfer;
            stall += (c_end - c_start) - c_comp.max(c_xfer);
        }
        if end == 0 {
            continue; // no cycle-consuming instructions anywhere
        }
        // PMU view: this layer's classified compute-wait cycles, summed
        // over the per-cluster per-layer banks
        let mut stall_breakdown = [0u64; N_STALL_REASONS];
        for run in runs {
            if let Some(bank) = run.pmu.per_layer.get(&(li as u32)) {
                for (acc, v) in stall_breakdown.iter_mut().zip(bank.stalls) {
                    *acc += v;
                }
            }
        }
        let cycles = end - start;
        // the layer's Activity cycle figure is its wall extent, not the
        // sum of span durations across concurrent clusters
        layer_act.cycles = cycles;
        let energy_mj = em.inference_mj(&layer_act);
        tb.span(
            SIM_PID,
            layers_tid,
            &layer.name,
            "layer",
            us(start),
            us(cycles),
            vec![
                ("bytes".to_string(), ArgValue::U64(bytes)),
                ("compute_busy".to_string(), ArgValue::U64(comp)),
                ("energy_pj".to_string(), ArgValue::F64(energy_mj * 1e9)),
                ("macs".to_string(), ArgValue::U64(macs)),
                ("stall".to_string(), ArgValue::U64(stall)),
                ("xfer_busy".to_string(), ArgValue::U64(xfer)),
            ],
        );
        layers.push(LayerStats {
            layer: li,
            name: layer.name.clone(),
            cycles,
            compute_busy: comp,
            xfer_busy: xfer,
            stall_cycles: stall,
            stall_breakdown,
            macs,
            bytes,
            mac_efficiency: if cycles > 0 {
                macs as f64 / (cycles as f64 * cfg.macs_per_cycle() as f64)
            } else {
                0.0
            },
            energy_mj,
            arith_intensity: energy::arithmetic_intensity(&layer_act),
            achieved_gops: if cycles > 0 {
                macs as f64 * 2.0 / (cycles as f64 * clock_ns)
            } else {
                0.0
            },
            activity: layer_act,
        });
    }

    // host orchestration tail, serialized after the slowest cluster
    let mut t = runs.iter().map(|r| r.cycles).max().unwrap_or(0);
    for step in &compiled.host_steps {
        tb.span(
            SIM_PID,
            host_tid,
            &step.layer,
            "host",
            us(t),
            us(step.host_cycles),
            Vec::new(),
        );
        folded.add(format!("host;host;{}", step.layer), step.host_cycles);
        t += step.host_cycles;
    }

    SimTrace { model: g.name.clone(), clock_ns, layers, trace: tb, folded }
}

/// Cycle-domain time-series sampling: simulate `g` traced, then bin
/// per-cluster compute utilization and per-component power into
/// `interval_cycles` windows pushed through a bounded [`RingSampler`]
/// (the `sample` CLI subcommand). Series layout:
/// `cluster{i}_util` per cluster, then `power_mw_total`, then one
/// `power_mw_{component}` per [`energy::COMPONENTS`] entry.
pub fn sample_timeseries(
    g: &Graph,
    cfg: &ArchConfig,
    interval_cycles: u64,
    capacity: usize,
) -> crate::Result<(SimResult, RingSampler)> {
    let compiled = compiler::compile(g, cfg)?;
    let penalty = dma_penalty(cfg);
    let mut runs = Vec::with_capacity(compiled.cluster_programs.len());
    let mut cluster_spans = Vec::with_capacity(compiled.cluster_programs.len());
    for prog in &compiled.cluster_programs {
        let (run, spans) = run_cluster_traced(cfg, prog, penalty);
        runs.push(run);
        cluster_spans.push(spans);
    }
    let result = finish(g, cfg, &compiled, &runs);

    let em = EnergyModel::fdsoi28().at_voltage(cfg.voltage, 0.85);
    let iv = interval_cycles.max(1);
    let n_windows = result.cycles.div_ceil(iv) as usize;
    let nclusters = cluster_spans.len();
    let mut series: Vec<String> = (0..nclusters).map(|ci| format!("cluster{ci}_util")).collect();
    series.push("power_mw_total".to_string());
    for c in energy::COMPONENTS {
        series.push(format!("power_mw_{c}"));
    }

    // distribute each span's busy cycles and energy across the windows it
    // overlaps — O(spans + windows), no per-cycle walk
    let mut busy = vec![vec![0u64; n_windows]; nclusters];
    let mut comp_mj = vec![[0f64; energy::COMPONENTS.len()]; n_windows];
    for (ci, spans) in cluster_spans.iter().enumerate() {
        for s in spans {
            if s.end == s.start {
                continue;
            }
            let comps = energy::EnergyBreakdown::from_activity(&em, &s.activity).components();
            let dur = (s.end - s.start) as f64;
            let mut w = (s.start / iv) as usize;
            let mut pos = s.start;
            while pos < s.end {
                let wend = (w as u64 + 1) * iv;
                let take = s.end.min(wend) - pos;
                if s.engine == Engine::Compute {
                    busy[ci][w] += take;
                }
                let frac = take as f64 / dur;
                for (acc, (_, mj)) in comp_mj[w].iter_mut().zip(comps) {
                    *acc += mj * frac;
                }
                pos += take;
                w += 1;
            }
        }
    }

    let mut sampler = RingSampler::new(iv as f64, capacity, series);
    for (w, comp) in comp_mj.iter().enumerate() {
        let wstart = w as u64 * iv;
        let wlen = (result.cycles - wstart).min(iv);
        let wms = wlen as f64 * cfg.clock_ns() * 1e-6;
        let mut v = Vec::with_capacity(nclusters + 1 + energy::COMPONENTS.len());
        for b in &busy {
            v.push(b[w] as f64 / wlen as f64);
        }
        let total: f64 = comp.iter().sum();
        v.push(total / wms);
        for mj in comp {
            v.push(mj / wms);
        }
        sampler.push(wstart as f64, v);
    }
    Ok((result, sampler))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Shape;
    use crate::models;

    #[test]
    fn tinycnn_simulates() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let r = simulate(&g, &ArchConfig::j3dai()).unwrap();
        assert_eq!(r.total_macs, g.total_macs());
        assert_eq!(r.activity.macs, g.total_macs());
        assert!(r.cycles > 0);
        assert!(r.mac_efficiency > 0.0 && r.mac_efficiency <= 1.0);
    }

    #[test]
    fn mbv1_efficiency_beats_mbv2() {
        // The paper's central Table I shape: MobileNetV1's plain conv
        // pipeline sustains much higher MAC/cycle than the branching MBv2.
        let cfg = ArchConfig::j3dai();
        let v1 = simulate(&models::paper_mbv1(), &cfg).unwrap();
        let v2 = simulate(&models::paper_mbv2(), &cfg).unwrap();
        assert!(
            v1.mac_efficiency > v2.mac_efficiency + 0.1,
            "v1={} v2={}",
            v1.mac_efficiency,
            v2.mac_efficiency
        );
    }

    #[test]
    fn seg_latency_largest() {
        let cfg = ArchConfig::j3dai();
        let v1 = simulate(&models::paper_mbv1(), &cfg).unwrap();
        let v2 = simulate(&models::paper_mbv2(), &cfg).unwrap();
        let sg = simulate(&models::paper_seg(), &cfg).unwrap();
        assert!(sg.latency_ms > v1.latency_ms);
        assert!(v1.latency_ms > v2.latency_ms);
    }

    #[test]
    fn seg_cannot_do_200fps() {
        // Table I prints "-" for segmentation power at 200 FPS: 7.43 ms
        // latency cannot sustain a 5 ms frame budget.
        let cfg = ArchConfig::j3dai();
        let sg = simulate(&models::paper_seg(), &cfg).unwrap();
        let em = crate::power::EnergyModel::fdsoi28();
        assert!(sg.latency_ms > 5.0, "latency={}", sg.latency_ms);
        assert!(sg.power_mw(&em, 200.0).is_none());
        assert!(sg.power_mw(&em, 30.0).is_some());
    }

    #[test]
    fn dmpa_off_slows_everything() {
        let g = models::mobilenet_v1(1, 4, Shape::new(48, 64, 3), 100);
        let on = simulate(&g, &ArchConfig::j3dai()).unwrap();
        let off_cfg = ArchConfig { dmpa_enabled: false, ..ArchConfig::j3dai() };
        let off = simulate(&g, &off_cfg).unwrap();
        // at alpha=1/4 compute dominates; the DMA penalty still shows (the
        // full-size sweep in benches/ablation_dmpa.rs shows the >2x gap)
        assert!(off.cycles as f64 > on.cycles as f64 * 1.5, "on={} off={}", on.cycles, off.cycles);
    }

    #[test]
    fn more_clusters_fewer_cycles() {
        let g = models::mobilenet_v1(1, 2, Shape::new(96, 128, 3), 100);
        let c2 = simulate(&g, &ArchConfig::scaled(2, 16, 8)).unwrap();
        let c6 = simulate(&g, &ArchConfig::scaled(6, 16, 8)).unwrap();
        assert!(c6.cycles < c2.cycles, "c2={} c6={}", c2.cycles, c6.cycles);
    }

    #[test]
    fn traced_matches_untraced() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let cfg = ArchConfig::j3dai();
        let plain = simulate(&g, &cfg).unwrap();
        let (traced, tr) = simulate_traced(&g, &cfg).unwrap();
        assert_eq!(plain.cycles, traced.cycles);
        assert_eq!(plain.activity.macs, traced.activity.macs);
        assert_eq!(plain.host_cycles, traced.host_cycles);
        // every graph layer got a stats row and a span on the layers track
        assert_eq!(tr.layers.len(), g.layers.len());
        // layer MACs sum back to the graph total
        assert_eq!(tr.layers.iter().map(|l| l.macs).sum::<u64>(), g.total_macs());
    }

    #[test]
    fn trace_has_compute_and_xfer_tracks() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let cfg = ArchConfig::j3dai();
        let (_, tr) = simulate_traced(&g, &cfg).unwrap();
        assert_eq!(tr.trace.thread_label(SIM_PID, 0), Some("cluster0/COMPUTE"));
        assert_eq!(tr.trace.thread_label(SIM_PID, 1), Some("cluster0/XFER"));
        let layers_tid = cfg.clusters as u32 * 2;
        assert_eq!(tr.trace.thread_label(SIM_PID, layers_tid), Some("layers"));
        assert_eq!(tr.trace.thread_label(SIM_PID, layers_tid + 1), Some("host"));
        // both engines actually carry spans, and host spans follow the clusters
        assert!(tr.trace.events.iter().any(|e| e.tid == 0));
        assert!(tr.trace.events.iter().any(|e| e.tid == 1));
        assert!(tr.trace.events.iter().any(|e| e.tid == layers_tid + 1));
        // per-layer busy never exceeds clusters * extent
        for l in &tr.layers {
            assert!(l.compute_busy <= l.cycles * cfg.clusters as u64, "{}", l.name);
            assert!(l.xfer_busy <= l.cycles * cfg.clusters as u64, "{}", l.name);
            assert!(l.mac_efficiency <= 1.0);
        }
    }

    #[test]
    fn layer_energy_and_intensity_populate() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let cfg = ArchConfig::j3dai();
        let (r, tr) = simulate_traced(&g, &cfg).unwrap();
        let em = EnergyModel::fdsoi28();
        let total = em.inference_mj(&r.activity);
        let layer_sum: f64 = tr.layers.iter().map(|l| l.energy_mj).sum();
        // span-attributed energy never exceeds the system total: controller
        // energy rides the compute timeline only, and setup spans fall
        // outside the layer table (see telemetry::energy)
        assert!(layer_sum > 0.0);
        assert!(layer_sum <= total * (1.0 + 1e-9), "layers={layer_sum} total={total}");
        for l in &tr.layers {
            assert!(l.energy_mj > 0.0, "{}", l.name);
            assert!(l.arith_intensity >= 0.0, "{}", l.name);
            assert!(
                l.achieved_gops > 0.0 && l.achieved_gops <= cfg.peak_gops() * 1.000001,
                "{}: {} GOPS vs peak {}",
                l.name,
                l.achieved_gops,
                cfg.peak_gops()
            );
            assert_eq!(l.activity.macs, l.macs, "{}", l.name);
            assert_eq!(l.activity.cycles, l.cycles, "{}", l.name);
        }
        // the layer trace spans carry the energy arg the table is built from
        let layers_tid = cfg.clusters as u32 * 2;
        assert!(tr
            .trace
            .events
            .iter()
            .filter(|e| e.tid == layers_tid)
            .all(|e| e.args.iter().any(|(k, _)| k == "energy_pj")));
    }

    #[test]
    fn cluster_pmu_accounts_for_total_cycles() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let r = simulate(&g, &ArchConfig::j3dai()).unwrap();
        assert!(!r.clusters.is_empty());
        for (ci, c) in r.clusters.iter().enumerate() {
            assert_eq!(
                c.pmu.total.accounted(),
                r.cycles,
                "cluster {ci}: busy+ctrl+stalls must cover the whole inference"
            );
        }
        // at least one cluster halts before the end-to-end cycle count
        // (host tail), so host_sync shows up
        let hs = StallReason::HostSync.index();
        assert!(r.clusters.iter().any(|c| c.pmu.total.stalls[hs] > 0));
    }

    #[test]
    fn folded_profile_covers_engine_busy_and_host() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let cfg = ArchConfig::j3dai();
        let (r, tr) = simulate_traced(&g, &cfg).unwrap();
        assert!(!tr.folded.is_empty());
        // stack weights = all span cycles + the host tail
        let busy: u64 = r.clusters.iter().map(|c| c.compute_busy + c.xfer_busy).sum();
        assert_eq!(tr.folded.total_weight(), busy + r.host_cycles);
        for (stack, w) in tr.folded.iter() {
            assert_eq!(stack.matches(';').count(), 2, "{stack}");
            assert!(w > 0);
        }
    }

    #[test]
    fn sample_timeseries_bins_utilization_and_power() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let cfg = ArchConfig::j3dai();
        let (r, sampler) = sample_timeseries(&g, &cfg, 256, 1 << 20).unwrap();
        assert_eq!(sampler.series().len(), cfg.clusters + 1 + energy::COMPONENTS.len());
        assert_eq!(sampler.len() as u64, r.cycles.div_ceil(256));
        assert_eq!(sampler.dropped(), 0);
        let mut total_mj = 0.0;
        for s in sampler.samples() {
            for (name, v) in sampler.series().iter().zip(&s.v) {
                assert!(v.is_finite(), "{name}={v}");
                if name.ends_with("_util") {
                    assert!((0.0..=1.0 + 1e-9).contains(v), "{name}={v}");
                } else {
                    assert!(*v >= 0.0, "{name}={v}");
                }
            }
            // window mJ = power_mw * window_ms; reconstruct the total
            let wlen = (r.cycles - s.t as u64).min(256);
            total_mj += s.v[cfg.clusters] * wlen as f64 * cfg.clock_ns() * 1e-6;
        }
        // energy binned into windows matches the span-attributed total
        let span_mj: f64 = {
            let (_, tr) = simulate_traced(&g, &cfg).unwrap();
            tr.trace
                .events
                .iter()
                .filter(|e| e.tid < cfg.clusters as u32 * 2)
                .filter_map(|e| e.args.iter().find(|(k, _)| k == "energy_pj"))
                .map(|(_, v)| v.as_f64().unwrap_or(0.0) * 1e-9)
                .sum()
        };
        assert!(
            (total_mj - span_mj).abs() < 1e-6 * span_mj.max(1.0),
            "windows={total_mj} spans={span_mj}"
        );
    }

    #[test]
    fn parallel_simulation_matches_serial() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let cfg = ArchConfig::j3dai();
        let compiled = compiler::compile(&g, &cfg).unwrap();
        let serial = simulate_compiled(&g, &cfg, &compiled);
        // 2 and 3 exercise uneven partitions of 6 clusters; 64 oversubscribes
        for threads in [2, 3, 64] {
            let par = simulate_compiled_threads(&g, &cfg, &compiled, threads);
            assert_eq!(serial.cycles, par.cycles, "threads={threads}");
            assert_eq!(serial.host_cycles, par.host_cycles, "threads={threads}");
            assert_eq!(serial.activity, par.activity, "threads={threads}");
            assert_eq!(serial.clusters.len(), par.clusters.len());
            for (ci, (a, b)) in serial.clusters.iter().zip(&par.clusters).enumerate() {
                assert_eq!(a.cycles, b.cycles, "cluster {ci}");
                assert_eq!(a.activity, b.activity, "cluster {ci}");
                assert_eq!(a.pmu, b.pmu, "cluster {ci}");
            }
        }
    }

    #[test]
    fn parallel_traced_matches_serial_trace() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let cfg = ArchConfig::j3dai();
        let compiled = compiler::compile(&g, &cfg).unwrap();
        let (rs, ts) = simulate_compiled_traced(&g, &cfg, &compiled);
        let (rp, tp) = simulate_compiled_traced_threads(&g, &cfg, &compiled, 4);
        assert_eq!(rs.cycles, rp.cycles);
        assert_eq!(rs.activity, rp.activity);
        assert_eq!(ts.trace.events, tp.trace.events);
        assert_eq!(ts.folded, tp.folded);
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn activity_macs_equal_graph_macs() {
        for g in [models::paper_mbv1(), models::paper_mbv2(), models::paper_seg()] {
            let r = simulate(&g, &ArchConfig::j3dai()).unwrap();
            assert_eq!(r.activity.macs, g.total_macs(), "{}", g.name);
        }
    }
}
