//! System-level simulation: compile a graph, run every cluster program,
//! merge the activity, add host orchestration and DMA-bus contention —
//! producing the numbers Table I/II report.

use crate::compiler::{self, scheduler, Compiled};
use crate::config::ArchConfig;
use crate::graph::Graph;
use crate::power::{self, Activity, EnergyModel};

/// Full result of simulating one inference.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub model: String,
    pub total_macs: u64,
    /// End-to-end cycles (slowest cluster + serial host sections).
    pub cycles: u64,
    pub activity: Activity,
    /// Latency at the configured clock, ms.
    pub latency_ms: f64,
    /// MAC/cycle efficiency (Table I/II metric).
    pub mac_efficiency: f64,
    /// Program footprint across clusters, bytes.
    pub program_bytes: usize,
    /// Host cycles (serial orchestration share).
    pub host_cycles: u64,
    /// Maximum sustainable frame rate.
    pub max_fps: f64,
}

impl SimResult {
    /// Power at a frame rate using an energy model (None if the frame rate
    /// exceeds what the latency allows — the paper prints "-" there).
    pub fn power_mw(&self, em: &EnergyModel, fps: f64) -> Option<f64> {
        if fps > self.max_fps {
            return None;
        }
        Some(em.power_mw(&self.activity, fps))
    }

    /// TOPs/W at a frame rate (Table I "Power efficiency").
    pub fn tops_per_watt(&self, em: &EnergyModel, fps: f64) -> Option<f64> {
        if fps > self.max_fps {
            return None;
        }
        Some(em.tops_per_watt(&self.activity, fps))
    }
}

/// Simulate one inference of `g` on `cfg`.
pub fn simulate(g: &Graph, cfg: &ArchConfig) -> crate::Result<SimResult> {
    let compiled = compiler::compile(g, cfg)?;
    Ok(simulate_compiled(g, cfg, &compiled))
}

/// Simulate from an already-compiled artifact (reused by the coordinator).
pub fn simulate_compiled(g: &Graph, cfg: &ArchConfig, compiled: &Compiled) -> SimResult {
    // DMA-bus contention: the 64-bit system interconnect is shared by all
    // clusters; when the DMPA is disabled every cluster's DMA traffic
    // serializes, modeled as a cycle multiplier equal to the cluster count.
    let dma_penalty = if cfg.dmpa_enabled { 1 } else { cfg.clusters as u64 };

    let mut activity = Activity::default();
    let mut slowest = 0u64;
    let mut busy_total = 0u64;
    for prog in &compiled.cluster_programs {
        let run = super::engine::run_cluster(cfg, prog, dma_penalty);
        slowest = slowest.max(run.cycles);
        busy_total += run.activity.busy_cluster_cycles;
        activity.macs += run.activity.macs;
        activity.local_sram_bytes += run.activity.local_sram_bytes;
        activity.dmpa_bytes += run.activity.dmpa_bytes;
        activity.dma_bytes += run.activity.dma_bytes;
        activity.tsv_bytes += run.activity.tsv_bytes;
        activity.alu_ops += run.activity.alu_ops;
    }
    let host_cycles = scheduler::host_total_cycles(&compiled.host_steps);
    let cycles = slowest + host_cycles;
    activity.cycles = cycles;
    activity.busy_cluster_cycles = busy_total;

    SimResult {
        model: g.name.clone(),
        total_macs: g.total_macs(),
        cycles,
        latency_ms: power::latency_ms(cfg, cycles),
        mac_efficiency: activity.macs as f64 / (cycles as f64 * cfg.macs_per_cycle() as f64),
        program_bytes: compiled.program_bytes(),
        host_cycles,
        max_fps: power::max_fps(cfg, cycles),
        activity,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Shape;
    use crate::models;

    #[test]
    fn tinycnn_simulates() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let r = simulate(&g, &ArchConfig::j3dai()).unwrap();
        assert_eq!(r.total_macs, g.total_macs());
        assert_eq!(r.activity.macs, g.total_macs());
        assert!(r.cycles > 0);
        assert!(r.mac_efficiency > 0.0 && r.mac_efficiency <= 1.0);
    }

    #[test]
    fn mbv1_efficiency_beats_mbv2() {
        // The paper's central Table I shape: MobileNetV1's plain conv
        // pipeline sustains much higher MAC/cycle than the branching MBv2.
        let cfg = ArchConfig::j3dai();
        let v1 = simulate(&models::paper_mbv1(), &cfg).unwrap();
        let v2 = simulate(&models::paper_mbv2(), &cfg).unwrap();
        assert!(
            v1.mac_efficiency > v2.mac_efficiency + 0.1,
            "v1={} v2={}",
            v1.mac_efficiency,
            v2.mac_efficiency
        );
    }

    #[test]
    fn seg_latency_largest() {
        let cfg = ArchConfig::j3dai();
        let v1 = simulate(&models::paper_mbv1(), &cfg).unwrap();
        let v2 = simulate(&models::paper_mbv2(), &cfg).unwrap();
        let sg = simulate(&models::paper_seg(), &cfg).unwrap();
        assert!(sg.latency_ms > v1.latency_ms);
        assert!(v1.latency_ms > v2.latency_ms);
    }

    #[test]
    fn seg_cannot_do_200fps() {
        // Table I prints "-" for segmentation power at 200 FPS: 7.43 ms
        // latency cannot sustain a 5 ms frame budget.
        let cfg = ArchConfig::j3dai();
        let sg = simulate(&models::paper_seg(), &cfg).unwrap();
        let em = crate::power::EnergyModel::fdsoi28();
        assert!(sg.latency_ms > 5.0, "latency={}", sg.latency_ms);
        assert!(sg.power_mw(&em, 200.0).is_none());
        assert!(sg.power_mw(&em, 30.0).is_some());
    }

    #[test]
    fn dmpa_off_slows_everything() {
        let g = models::mobilenet_v1(1, 4, Shape::new(48, 64, 3), 100);
        let on = simulate(&g, &ArchConfig::j3dai()).unwrap();
        let off_cfg = ArchConfig { dmpa_enabled: false, ..ArchConfig::j3dai() };
        let off = simulate(&g, &off_cfg).unwrap();
        // at alpha=1/4 compute dominates; the DMA penalty still shows (the
        // full-size sweep in benches/ablation_dmpa.rs shows the >2x gap)
        assert!(off.cycles as f64 > on.cycles as f64 * 1.5, "on={} off={}", on.cycles, off.cycles);
    }

    #[test]
    fn more_clusters_fewer_cycles() {
        let g = models::mobilenet_v1(1, 2, Shape::new(96, 128, 3), 100);
        let c2 = simulate(&g, &ArchConfig::scaled(2, 16, 8)).unwrap();
        let c6 = simulate(&g, &ArchConfig::scaled(6, 16, 8)).unwrap();
        assert!(c6.cycles < c2.cycles, "c2={} c6={}", c2.cycles, c6.cycles);
    }

    #[test]
    fn activity_macs_equal_graph_macs() {
        for g in [models::paper_mbv1(), models::paper_mbv2(), models::paper_seg()] {
            let r = simulate(&g, &ArchConfig::j3dai()).unwrap();
            assert_eq!(r.activity.macs, g.total_macs(), "{}", g.name);
        }
    }
}
