//! Frame-loop coordinator — the "system software" tying the sensor model,
//! the cycle simulator and the PJRT functional path into a running service.
//!
//! Pipeline (std threads + channels; the offline registry has no tokio):
//!
//! ```text
//! [sensor thread] --frames--> [inference worker] --records--> [caller]
//!      |  FPS governor             | PJRT infer (functional output)
//!      |  (30 / 200 FPS)           | cycle-sim stats (latency/energy)
//! ```
//!
//! The worker executes the *AOT JAX artifact* through PJRT — python never
//! runs here — while accounting latency/energy with the cycle simulator's
//! per-inference numbers, exactly how the real chip would pair its DNN
//! accelerator with its host runtime.

use std::path::Path;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::config::ArchConfig;
use crate::graph::Shape;
use crate::power::EnergyModel;
use crate::runtime::Runtime;
use crate::sensor::PixelArray;
use crate::sim::{self, SimResult};

/// One processed frame.
#[derive(Debug, Clone)]
pub struct FrameRecord {
    pub frame_idx: u64,
    /// argmax class (classifiers) or dominant class (segmentation).
    pub top_class: usize,
    /// wall-clock service time of the PJRT execution.
    pub service_us: f64,
    /// modeled accelerator latency (from the cycle simulator), ms.
    pub modeled_latency_ms: f64,
    /// modeled energy of this inference, mJ.
    pub modeled_energy_mj: f64,
}

/// Aggregated run statistics.
#[derive(Debug, Clone)]
pub struct RunStats {
    pub model: String,
    pub frames: u64,
    pub wall_s: f64,
    pub achieved_fps: f64,
    pub mean_service_us: f64,
    pub p99_service_us: f64,
    pub modeled_latency_ms: f64,
    pub modeled_power_mw_at_fps: f64,
    pub records: Vec<FrameRecord>,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub target_fps: f64,
    pub frames: u64,
    pub arch: ArchConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { target_fps: 30.0, frames: 30, arch: ArchConfig::j3dai() }
    }
}

/// The running service.
pub struct Coordinator {
    runtime: Runtime,
    energy: EnergyModel,
    cfg: CoordinatorConfig,
}

impl Coordinator {
    /// Load all artifacts from `dir` and pre-simulate each model.
    pub fn new(dir: &Path, cfg: CoordinatorConfig) -> crate::Result<Self> {
        let mut runtime = Runtime::new()?;
        let n = runtime.load_all(dir)?;
        anyhow::ensure!(n > 0, "no artifacts in {}", dir.display());
        log::info!("coordinator: loaded {n} artifacts on {}", runtime.platform());
        Ok(Coordinator { runtime, energy: EnergyModel::fdsoi28(), cfg })
    }

    /// Cycle-simulate the graph twin of an artifact model.
    pub fn presimulate(&self, name: &str) -> crate::Result<SimResult> {
        let g = crate::models::artifact_graph(name)
            .ok_or_else(|| anyhow::anyhow!("no graph twin for artifact {name}"))?;
        sim::simulate(&g, &self.cfg.arch)
    }

    /// Run the frame loop for one model; returns aggregated stats.
    pub fn run_model(&self, name: &str) -> crate::Result<RunStats> {
        let entry = self
            .runtime
            .entry(name)
            .ok_or_else(|| anyhow::anyhow!("model {name} not loaded"))?
            .clone();
        let simr = self.presimulate(name)?;
        let energy_mj = self.energy.inference_mj(&simr.activity);

        // sensor thread: paced frame production with backpressure (bounded
        // channel of 2 frames — the double-buffered L2 frame slots)
        let (tx, rx) = mpsc::sync_channel::<(u64, crate::sim::functional::Tensor)>(2);
        let frames = self.cfg.frames;
        let period = Duration::from_secs_f64(1.0 / self.cfg.target_fps);
        let shape: Shape = entry.input_shape;
        let producer = std::thread::spawn(move || {
            let pixels = PixelArray::new(0x13DA1);
            let t0 = Instant::now();
            for i in 0..frames {
                let due = period * i as u32;
                if let Some(sleep) = due.checked_sub(t0.elapsed()) {
                    std::thread::sleep(sleep);
                }
                let frame = pixels.capture(i, shape);
                if tx.send((i, frame)).is_err() {
                    break; // consumer gone
                }
            }
        });

        let mut records = Vec::with_capacity(frames as usize);
        let t0 = Instant::now();
        while let Ok((i, frame)) = rx.recv() {
            let s0 = Instant::now();
            let out = self.runtime.infer(name, &frame)?;
            let service_us = s0.elapsed().as_secs_f64() * 1e6;
            let top_class = argmax_class(&out, &entry.output_dims);
            records.push(FrameRecord {
                frame_idx: i,
                top_class,
                service_us,
                modeled_latency_ms: simr.latency_ms,
                modeled_energy_mj: energy_mj,
            });
        }
        producer.join().map_err(|_| anyhow::anyhow!("sensor thread panicked"))?;
        let wall_s = t0.elapsed().as_secs_f64();

        let mut service: Vec<f64> = records.iter().map(|r| r.service_us).collect();
        service.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99 = service[((service.len() as f64 * 0.99) as usize).min(service.len() - 1)];
        let mean = service.iter().sum::<f64>() / service.len() as f64;
        let achieved_fps = records.len() as f64 / wall_s;
        Ok(RunStats {
            model: name.to_string(),
            frames: records.len() as u64,
            wall_s,
            achieved_fps,
            mean_service_us: mean,
            p99_service_us: p99,
            modeled_latency_ms: simr.latency_ms,
            modeled_power_mw_at_fps: self
                .energy
                .power_mw(&simr.activity, self.cfg.target_fps.min(simr.max_fps)),
            records,
        })
    }

    pub fn model_names(&self) -> Vec<String> {
        self.runtime.model_names().into_iter().map(String::from).collect()
    }
}

/// argmax over the class axis: classifiers output (1, C); segmentation
/// outputs (H, W, C) — we return the most frequent per-pixel argmax.
pub fn argmax_class(out: &[u8], dims: &[usize]) -> usize {
    let c = *dims.last().unwrap_or(&1);
    if c == 0 || out.is_empty() {
        return 0;
    }
    if dims.len() <= 2 {
        return out.iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, _)| i % c).unwrap_or(0);
    }
    let mut hist = vec![0u32; c];
    for px in out.chunks_exact(c) {
        let am = px.iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, _)| i).unwrap_or(0);
        hist[am] += 1;
    }
    hist.iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, _)| i).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_classifier() {
        let out = [1u8, 9, 3];
        assert_eq!(argmax_class(&out, &[1, 3]), 1);
    }

    #[test]
    fn argmax_segmentation_majority() {
        // two pixels argmax=2, one pixel argmax=0
        let out = [9u8, 1, 2, 1, 2, 9, 0, 0, 7];
        assert_eq!(argmax_class(&out, &[1, 3, 3]), 2);
    }

    #[test]
    fn default_config_sane() {
        let c = CoordinatorConfig::default();
        assert_eq!(c.target_fps, 30.0);
        assert!(c.frames > 0);
    }
}
