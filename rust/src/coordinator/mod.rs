//! Frame-loop coordinator — the "system software" tying the sensor model,
//! the cycle simulator and the PJRT functional path into a running service.
//!
//! Pipeline (std threads + channels; the offline registry has no tokio):
//!
//! ```text
//! [sensor thread] --frames--> [worker 0..M-1] --(seq, result)--> [collector]
//!      |  FPS governor            | PJRT infer (functional output)
//!      |  (30 / 200 FPS)          | cycle-sim stats (latency/energy)
//! ```
//!
//! M inference workers (`CoordinatorConfig::workers`) drain the bounded
//! frame channel; frames carry sequence numbers, and a collector reorders
//! worker results so records, metrics and time-series snapshots are
//! emitted in frame order — the published artifacts are identical for any
//! worker count. The workers execute the *AOT JAX artifact* through PJRT —
//! python never runs here — while accounting latency/energy with the cycle
//! simulator's per-inference numbers, exactly how the real chip would pair
//! its DNN accelerator with its host runtime.
//!
//! The loop is instrumented end to end: every frame produces `capture` and
//! `infer` wall-time spans (pid [`FRAME_PID`]; worker threads are named
//! `infer-0..M-1`), and the service publishes frame-loop metrics
//! (`j3dai_frames_total`, `j3dai_worker_frames_total{worker}`,
//! `j3dai_inference_service_us`, `j3dai_capture_us`, `j3dai_queue_depth`,
//! `j3dai_achieved_fps`) plus the energy series (`j3dai_energy_mj_total`
//! and friends — see [`telemetry::energy`]), their per-cluster splits and
//! the PMU stall counters (`j3dai_stall_cycles_total{cluster,reason}`)
//! into the coordinator's [`Telemetry`] registry — [`RunStats`] is derived
//! from those series, not from a private tally. Each processed frame also
//! pushes a snapshot (queue depth, fps, power, cumulative energy) into the
//! ring sampler behind `/timeseries.json`, and the service histogram
//! carries an exemplar naming the slowest frame. The registry/trace pair
//! is held behind an [`Arc`] so the live exporter (`j3dai serve
//! --metrics-addr`, [`crate::telemetry::MetricsServer`]) can scrape it
//! while frames flow.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::ArchConfig;
use crate::graph::{Graph, Shape};
use crate::power::{Activity, EnergyModel};
use crate::runtime::Runtime;
use crate::sensor::PixelArray;
use crate::sim::functional::Tensor;
use crate::sim::{self, SimResult};
use crate::telemetry::{
    self, ArgValue, ClusterEnergyMetrics, Counter, EnergyMetrics, RingSampler, StallMetrics,
    Telemetry, TraceEvent, FRAME_PID, SERVICE_US_BUCKETS,
};

/// One processed frame.
#[derive(Debug, Clone)]
pub struct FrameRecord {
    pub frame_idx: u64,
    /// argmax class (classifiers) or dominant class (segmentation).
    pub top_class: usize,
    /// wall-clock service time of the PJRT execution.
    pub service_us: f64,
    /// modeled accelerator latency (from the cycle simulator), ms.
    pub modeled_latency_ms: f64,
    /// modeled energy of this inference, mJ.
    pub modeled_energy_mj: f64,
}

/// Aggregated run statistics.
#[derive(Debug, Clone)]
pub struct RunStats {
    pub model: String,
    pub frames: u64,
    pub wall_s: f64,
    pub achieved_fps: f64,
    pub mean_service_us: f64,
    pub p99_service_us: f64,
    pub modeled_latency_ms: f64,
    pub modeled_power_mw_at_fps: f64,
    pub records: Vec<FrameRecord>,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub target_fps: f64,
    pub frames: u64,
    /// Inference workers draining the frame channel (clamped to >= 1).
    /// Frames are sequence-numbered and reassembled in order, so records
    /// and published metrics are identical for any worker count.
    pub workers: usize,
    /// Host threads for the cluster-parallel pre-simulation
    /// (see [`sim::simulate_threads`]).
    pub sim_threads: usize,
    pub arch: ArchConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            target_fps: 30.0,
            frames: 30,
            workers: 1,
            sim_threads: 1,
            arch: ArchConfig::j3dai(),
        }
    }
}

/// The running service.
pub struct Coordinator {
    runtime: Runtime,
    energy: EnergyModel,
    cfg: CoordinatorConfig,
    telemetry: Arc<Telemetry>,
}

impl Coordinator {
    /// Load all artifacts from `dir` and pre-simulate each model.
    pub fn new(dir: &Path, cfg: CoordinatorConfig) -> crate::Result<Self> {
        let mut runtime = Runtime::new()?;
        let n = runtime.load_all(dir)?;
        anyhow::ensure!(n > 0, "no artifacts in {}", dir.display());
        log::info!("coordinator: loaded {n} artifacts on {}", runtime.platform());
        Ok(Coordinator {
            runtime,
            energy: EnergyModel::fdsoi28(),
            cfg,
            telemetry: Arc::new(Telemetry::new(true)),
        })
    }

    /// The service's telemetry domain (frame spans + frame-loop metrics).
    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    /// Shared handle to the telemetry domain — hand this to a
    /// [`crate::telemetry::MetricsServer`] so `/metrics` and `/trace.json`
    /// stay live while the frame loop runs.
    pub fn telemetry_handle(&self) -> Arc<Telemetry> {
        Arc::clone(&self.telemetry)
    }

    /// Cycle-simulate the graph twin of an artifact model.
    pub fn presimulate(&self, name: &str) -> crate::Result<SimResult> {
        let g = crate::models::artifact_graph(name)
            .ok_or_else(|| anyhow::anyhow!("no graph twin for artifact {name}"))?;
        sim::simulate_threads(&g, &self.cfg.arch, self.cfg.sim_threads)
    }

    /// Run the frame loop for one model; returns aggregated stats.
    pub fn run_model(&self, name: &str) -> crate::Result<RunStats> {
        let entry = self
            .runtime
            .entry(name)
            .ok_or_else(|| anyhow::anyhow!("model {name} not loaded"))?
            .clone();
        let simr = self.presimulate(name)?;
        let (tel, em) = (&self.telemetry, &self.energy);
        run_frame_loop(name, entry.input_shape, &self.cfg, tel, &simr, em, |frame| {
            let out = self.runtime.infer(name, frame)?;
            Ok(argmax_class(&out, &entry.output_dims))
        })
    }

    pub fn model_names(&self) -> Vec<String> {
        self.runtime.model_names().into_iter().map(String::from).collect()
    }
}

/// Run the frame loop against the *functional* simulator instead of PJRT —
/// no artifacts or accelerator runtime needed. Powers `j3dai metrics` and
/// the integration tests; the loop body (sensor thread, backpressure,
/// telemetry) is exactly the one [`Coordinator::run_model`] uses.
pub fn run_functional_loop(
    g: &Graph,
    ccfg: &CoordinatorConfig,
    tel: &Telemetry,
) -> crate::Result<RunStats> {
    let simr = sim::simulate_threads(g, &ccfg.arch, ccfg.sim_threads)?;
    let energy = EnergyModel::fdsoi28();
    run_frame_loop(&g.name, g.input, ccfg, tel, &simr, &energy, |frame| {
        let out = sim::functional::run_final(g, frame);
        Ok(argmax_class(&out.data, &[out.shape.h, out.shape.w, out.shape.c]))
    })
}

/// One worker's per-frame output, posted to the collector with its frame
/// sequence number for in-order reassembly.
struct WorkerDone {
    top_class: usize,
    service_us: f64,
    /// Channel depth observed as the worker dequeued this frame.
    queue_depth: u64,
}

/// The shared frame loop: paced sensor thread, bounded channel, M
/// inference workers, in-order reassembly, per-frame spans and metrics,
/// aggregation. `infer` classifies one frame (its wall time is the
/// service-time metric) and may be called from any worker thread;
/// `simr`/`em` supply the modeled latency/energy figures each processed
/// frame accounts into the registry.
fn run_frame_loop(
    model: &str,
    shape: Shape,
    ccfg: &CoordinatorConfig,
    tel: &Telemetry,
    simr: &SimResult,
    em: &EnergyModel,
    infer: impl Fn(&Tensor) -> crate::Result<usize> + Sync,
) -> crate::Result<RunStats> {
    let workers = ccfg.workers.max(1);
    let modeled_latency_ms = simr.latency_ms;
    let modeled_energy_mj = em.inference_mj(&simr.activity);
    // energy gauges report the rate the loop is paced at, capped at what the
    // modeled latency can sustain (the paper prints "-" above that rate)
    let modeled_fps = ccfg.target_fps.min(simr.max_fps);
    let modeled_power_mw = em.power_mw(&simr.activity, modeled_fps);
    let labels: &[(&str, &str)] = &[("model", model)];
    let energy_metrics = EnergyMetrics::register(&tel.registry, model);
    // per-cluster attribution: the sim result's cluster Activities partition
    // the inference, and each cluster's PMU bank classifies its idle cycles
    let cluster_energy = ClusterEnergyMetrics::register(&tel.registry, model, simr.clusters.len());
    let cluster_acts: Vec<Activity> = simr.clusters.iter().map(|c| c.activity).collect();
    let stall_metrics = StallMetrics::register(&tel.registry, model, simr.clusters.len());
    let frames_total =
        tel.registry.counter_with("j3dai_frames_total", labels, "Frames fully processed");
    let service_hist = tel.registry.histogram_with(
        "j3dai_inference_service_us",
        labels,
        "Per-frame inference service time (us)",
        SERVICE_US_BUCKETS,
    );
    let capture_hist = tel.registry.histogram_with(
        "j3dai_capture_us",
        labels,
        "Sensor capture time (us)",
        SERVICE_US_BUCKETS,
    );
    let depth_gauge =
        tel.registry.gauge_with("j3dai_queue_depth", labels, "Frames waiting in the channel");
    let fps_gauge =
        tel.registry.gauge_with("j3dai_achieved_fps", labels, "Achieved frame rate of last run");
    // per-worker share of the processed frames (load-balance visibility)
    let worker_frames: Vec<Counter> = (0..workers)
        .map(|wi| {
            let w = format!("{wi}");
            tel.registry.counter_with(
                "j3dai_worker_frames_total",
                &[("model", model), ("worker", w.as_str())],
                "Frames processed per inference worker",
            )
        })
        .collect();
    // snapshots: RunStats is derived from the registry deltas of this run,
    // so several runs can share one Telemetry domain
    let (count0, sum0, n0) = (frames_total.get(), service_hist.sum(), service_hist.count());
    // live time series for /timeseries.json: one snapshot per processed
    // frame (wall-clock timestamps; no coalescing — frames ARE the grid)
    let series = ["queue_depth", "achieved_fps", "power_mw", "energy_mj_total"];
    tel.install_sampler(RingSampler::new(0.0, 1024, series.map(String::from).into()));
    tel.name_process(FRAME_PID, "frame-loop");
    tel.name_thread(FRAME_PID, 0, "capture");
    for wi in 0..workers {
        tel.name_thread(FRAME_PID, 1 + wi as u32, &format!("infer-{wi}"));
    }

    // channels: the bounded frame channel (capacity 2 — the double-buffered
    // L2 frame slots) feeds the workers; the result channel carries
    // sequence-numbered outputs back to the collector. Capture timestamps
    // ride the frame channel so workers can record their spans on the
    // shared telemetry timebase.
    let (tx, rx) = mpsc::sync_channel::<(u64, Tensor, f64, f64)>(2);
    let frame_rx = Mutex::new(rx);
    let (res_tx, res_rx) = mpsc::channel::<(u64, crate::Result<WorkerDone>)>();
    let frames = ccfg.frames;
    let period = Duration::from_secs_f64(1.0 / ccfg.target_fps);
    let depth = AtomicU64::new(0);
    let base = Instant::now();
    let base_us = tel.now_us();

    let mut records = Vec::with_capacity(frames as usize);
    let mut loop_err = None;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        // sensor thread: paced frame production with backpressure
        let depth_ref = &depth;
        s.spawn(move || {
            let pixels = PixelArray::new(0x13DA1);
            let p0 = Instant::now();
            for i in 0..frames {
                let due = period * i as u32;
                if let Some(sleep) = due.checked_sub(p0.elapsed()) {
                    std::thread::sleep(sleep);
                }
                let cap_ts = base_us + base.elapsed().as_secs_f64() * 1e6;
                let frame = pixels.capture(i, shape);
                let cap_dur = base_us + base.elapsed().as_secs_f64() * 1e6 - cap_ts;
                depth_ref.fetch_add(1, Ordering::Relaxed);
                if tx.send((i, frame, cap_ts, cap_dur)).is_err() {
                    break; // all workers gone
                }
            }
        });

        // M inference workers share the frame channel behind a mutex (the
        // guard drops at the end of the `recv` statement, before inference
        // runs) and post sequence-numbered results; errors are forwarded
        // to the collector
        let frame_rx = &frame_rx;
        let infer = &infer;
        let capture_hist = &capture_hist;
        let service_hist = &service_hist;
        let worker_frames = &worker_frames;
        for wi in 0..workers {
            let res_tx = res_tx.clone();
            s.spawn(move || loop {
                let msg = frame_rx.lock().unwrap().recv();
                let Ok((i, frame, cap_ts, cap_dur)) = msg else { break };
                let queue_depth = depth_ref.fetch_sub(1, Ordering::Relaxed).saturating_sub(1);
                capture_hist.observe(cap_dur);
                tel.record(TraceEvent {
                    name: "capture".to_string(),
                    cat: model.to_string(),
                    pid: FRAME_PID,
                    tid: 0,
                    ts_us: cap_ts,
                    dur_us: cap_dur,
                    args: vec![("frame".to_string(), ArgValue::U64(i))],
                });
                let s0 = tel.now_us();
                let res = infer(&frame).map(|top_class| {
                    let service_us = tel.now_us() - s0;
                    tel.record(TraceEvent {
                        name: "infer".to_string(),
                        cat: model.to_string(),
                        pid: FRAME_PID,
                        tid: 1 + wi as u32,
                        ts_us: s0,
                        dur_us: service_us,
                        args: vec![
                            ("frame".to_string(), ArgValue::U64(i)),
                            ("top_class".to_string(), ArgValue::U64(top_class as u64)),
                            ("worker".to_string(), ArgValue::U64(wi as u64)),
                        ],
                    });
                    // the exemplar pins the worst frame's id onto the hot
                    // bucket, so a scrape can jump straight from the
                    // histogram to the trace span
                    service_hist.observe_with_exemplar(service_us, &format!("frame{i}"));
                    worker_frames[wi].inc();
                    WorkerDone { top_class, service_us, queue_depth }
                });
                let failed = res.is_err();
                if res_tx.send((i, res)).is_err() || failed {
                    break;
                }
            });
        }
        drop(res_tx);

        // collector: reassemble results in frame order — all registry,
        // sampler and record bookkeeping happens here, on one thread, so
        // downstream consumers observe the same sequences as with 1 worker
        let mut pending: BTreeMap<u64, WorkerDone> = BTreeMap::new();
        let mut next_seq = 0u64;
        while let Ok((i, res)) = res_rx.recv() {
            match res {
                Err(e) => {
                    loop_err = Some(e);
                    break;
                }
                Ok(done) => {
                    pending.insert(i, done);
                }
            }
            while let Some(done) = pending.remove(&next_seq) {
                depth_gauge.set(done.queue_depth as f64);
                frames_total.inc();
                energy_metrics.record_inference(em, &simr.activity, modeled_fps);
                cluster_energy.record_inference(em, &cluster_acts);
                stall_metrics.record(simr.clusters.iter().map(|c| &c.pmu));
                let fps_now = (records.len() + 1) as f64 / t0.elapsed().as_secs_f64().max(1e-9);
                tel.sample(
                    tel.now_us(),
                    vec![
                        done.queue_depth as f64,
                        fps_now,
                        modeled_power_mw,
                        energy_metrics.total_mj(),
                    ],
                );
                records.push(FrameRecord {
                    frame_idx: next_seq,
                    top_class: done.top_class,
                    service_us: done.service_us,
                    modeled_latency_ms,
                    modeled_energy_mj,
                });
                next_seq += 1;
            }
        }
        if loop_err.is_some() {
            // a worker died mid-run: drain the frame channel so a producer
            // parked on the bounded send can finish and the scope can join
            while frame_rx.lock().unwrap().recv().is_ok() {}
        }
    });
    if let Some(e) = loop_err {
        return Err(e);
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let done = frames_total.get() - count0;
    let (dsum, dn) = (service_hist.sum() - sum0, service_hist.count() - n0);
    let mean = if dn > 0 { dsum / dn as f64 } else { 0.0 };
    let stats = aggregate_stats(
        model,
        records,
        done,
        mean,
        wall_s,
        modeled_latency_ms,
        modeled_power_mw,
    );
    fps_gauge.set(stats.achieved_fps);
    Ok(stats)
}

/// Fold records into [`RunStats`]. Total-function by construction: zero
/// frames (a `frames == 0` config, or a producer that died before its first
/// send) yields a well-formed all-zero result instead of an index underflow.
fn aggregate_stats(
    model: &str,
    records: Vec<FrameRecord>,
    frames: u64,
    mean_service_us: f64,
    wall_s: f64,
    modeled_latency_ms: f64,
    modeled_power_mw_at_fps: f64,
) -> RunStats {
    let mut service: Vec<f64> = records.iter().map(|r| r.service_us).collect();
    let p99 = if service.is_empty() {
        0.0
    } else {
        telemetry::percentile_unsorted(&mut service, 99.0)
    };
    let achieved_fps = if wall_s > 0.0 { records.len() as f64 / wall_s } else { 0.0 };
    RunStats {
        model: model.to_string(),
        frames,
        wall_s,
        achieved_fps,
        mean_service_us,
        p99_service_us: p99,
        modeled_latency_ms,
        modeled_power_mw_at_fps,
        records,
    }
}

/// argmax over the class axis: classifiers output (1, C); segmentation
/// outputs (H, W, C) — we return the most frequent per-pixel argmax.
pub fn argmax_class(out: &[u8], dims: &[usize]) -> usize {
    let c = *dims.last().unwrap_or(&1);
    if c == 0 || out.is_empty() {
        return 0;
    }
    if dims.len() <= 2 {
        return out.iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, _)| i % c).unwrap_or(0);
    }
    let mut hist = vec![0u32; c];
    for px in out.chunks_exact(c) {
        let am = px.iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, _)| i).unwrap_or(0);
        hist[am] += 1;
    }
    hist.iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, _)| i).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_classifier() {
        let out = [1u8, 9, 3];
        assert_eq!(argmax_class(&out, &[1, 3]), 1);
    }

    #[test]
    fn argmax_segmentation_majority() {
        // two pixels argmax=2, one pixel argmax=0
        let out = [9u8, 1, 2, 1, 2, 9, 0, 0, 7];
        assert_eq!(argmax_class(&out, &[1, 3, 3]), 2);
    }

    #[test]
    fn default_config_sane() {
        let c = CoordinatorConfig::default();
        assert_eq!(c.target_fps, 30.0);
        assert!(c.frames > 0);
    }

    #[test]
    fn aggregate_handles_zero_frames() {
        // regression: the old path indexed service[len-1] and divided by
        // len, both of which blow up on an empty run
        let s = aggregate_stats("m", Vec::new(), 0, 0.0, 0.01, 1.0, 2.0);
        assert_eq!(s.frames, 0);
        assert_eq!(s.mean_service_us, 0.0);
        assert_eq!(s.p99_service_us, 0.0);
        assert_eq!(s.achieved_fps, 0.0);
        assert!(s.records.is_empty());
        assert_eq!(s.modeled_latency_ms, 1.0);
    }

    #[test]
    fn aggregate_p99_uses_ceil_rank() {
        let rec = |us: f64| FrameRecord {
            frame_idx: 0,
            top_class: 0,
            service_us: us,
            modeled_latency_ms: 0.0,
            modeled_energy_mj: 0.0,
        };
        let records: Vec<FrameRecord> = [10.0, 20.0, 1000.0].map(rec).into();
        let s = aggregate_stats("m", records, 3, 0.0, 1.0, 0.0, 0.0);
        // 3 samples: truncation would pick index 2 here too, but ceil-rank
        // guarantees the tail value for every small n
        assert_eq!(s.p99_service_us, 1000.0);
        assert_eq!(s.achieved_fps, 3.0);
    }
}
