//! Crate-wide telemetry: metrics registry, span tracing, and exporters.
//!
//! The paper's whole argument is PPA — per-inference latency, power and MAC
//! efficiency — so every layer of this stack can explain *where* its cycles
//! and microseconds go:
//!
//! - [`metrics`] — lock-cheap counters/gauges/histograms with a
//!   Prometheus-style text renderer (`j3dai metrics`).
//! - [`trace`] — span collection and the Chrome trace-event exporter
//!   (`j3dai trace --model mbv1 --out trace.json`, open in Perfetto).
//! - [`energy`] — Activity → joules attribution: per-span `energy_pj`
//!   trace args, per-component energy counters, power/TOPS-per-W gauges.
//! - [`http`] — the `/metrics` + `/trace.json` exporter behind
//!   `j3dai serve --metrics-addr` (std::net, blocking, scrape-grade).
//! - [`json`] — dependency-free JSON emit/parse shared by the exporters.
//!
//! Span producers live next to the code they observe: the cycle engine
//! ([`crate::sim::engine::run_cluster_traced`]) records per-instruction
//! spans on per-cluster COMPUTE/XFER tracks, the system simulator
//! ([`crate::sim::simulate_traced`]) adds per-layer and host spans, the
//! compiler ([`crate::compiler::compile_traced`]) records per-pass wall
//! spans, and the coordinator publishes per-frame spans and the frame-loop
//! metrics. Tracing is strictly opt-in: the untraced sim path is
//! monomorphized over a no-op sink, so disabled tracing costs nothing
//! (asserted by `tests/telemetry_integration.rs`).

pub mod energy;
pub mod http;
pub mod json;
pub mod metrics;
pub mod pmu;
pub mod profile;
pub mod sampler;
pub mod trace;

pub use energy::{
    arithmetic_intensity, span_energy_pj, ClusterEnergyMetrics, EnergyBreakdown, EnergyMetrics,
};
pub use http::MetricsServer;
pub use metrics::{Counter, Exemplar, FCounter, Gauge, Histogram, Registry};
pub use pmu::{PmuBank, PmuCounters, StallMetrics, StallReason, N_STALL_REASONS, STALL_REASONS};
pub use profile::FoldedProfile;
pub use sampler::RingSampler;
pub use trace::{ArgValue, TraceBuilder, TraceEvent, COMPILER_PID, FRAME_PID, SIM_PID};

use std::sync::Mutex;
use std::time::Instant;

/// Service-time histogram bounds in microseconds (frame loop).
pub const SERVICE_US_BUCKETS: &[f64] = &[
    50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0, 10_000.0, 25_000.0, 50_000.0, 100_000.0,
];

/// Compiler-pass duration histogram bounds in microseconds.
pub const PASS_US_BUCKETS: &[f64] =
    &[10.0, 50.0, 100.0, 500.0, 1000.0, 5000.0, 25_000.0, 100_000.0];

/// Exact nearest-rank percentile with a **ceil-based rank**: for `n`
/// samples and percentile `p`, the rank is `ceil(p/100 * n)` (1-based), so
/// small sample counts report the tail rather than the median (p99 of 10
/// samples is the maximum, not the 9th value truncation would give).
///
/// `sorted` must be ascending; returns NaN on an empty slice. This is the
/// one shared percentile implementation — the coordinator, report and
/// benches all call it.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let n = sorted.len();
    let rank = ((p / 100.0) * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Sort samples and take a percentile (convenience for callers holding an
/// unsorted buffer).
pub fn percentile_unsorted(samples: &mut [f64], p: f64) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    percentile(samples, p)
}

/// One telemetry domain: a metrics registry plus an optional wall-clock
/// span collector. Metrics are always live (atomic-only hot path); span
/// recording is gated on `tracing` and costs one branch when off.
pub struct Telemetry {
    tracing: bool,
    t0: Instant,
    pub registry: Registry,
    trace: Mutex<TraceBuilder>,
    sampler: Mutex<Option<RingSampler>>,
}

impl Default for Telemetry {
    fn default() -> Self {
        Self::new(false)
    }
}

impl Telemetry {
    pub fn new(tracing: bool) -> Self {
        Telemetry {
            tracing,
            t0: Instant::now(),
            registry: Registry::new(),
            trace: Mutex::new(TraceBuilder::new()),
            sampler: Mutex::new(None),
        }
    }

    pub fn disabled() -> Self {
        Self::new(false)
    }

    pub fn tracing(&self) -> bool {
        self.tracing
    }

    /// Microseconds since this domain was created (the wall-span timebase).
    pub fn now_us(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() * 1e6
    }

    /// Record a span (no-op unless tracing is enabled).
    pub fn record(&self, ev: TraceEvent) {
        if self.tracing {
            self.trace.lock().unwrap().push(ev);
        }
    }

    pub fn name_thread(&self, pid: u32, tid: u32, label: &str) {
        if self.tracing {
            self.trace.lock().unwrap().name_thread(pid, tid, label);
        }
    }

    pub fn name_process(&self, pid: u32, label: &str) {
        if self.tracing {
            self.trace.lock().unwrap().name_process(pid, label);
        }
    }

    /// Run `f`, recording it as a wall-time span when tracing is on.
    pub fn wall_span<T>(&self, pid: u32, tid: u32, name: &str, cat: &str, f: impl FnOnce() -> T) -> T {
        if !self.tracing {
            return f();
        }
        let ts = self.now_us();
        let r = f();
        let dur = self.now_us() - ts;
        self.record(TraceEvent {
            name: name.to_string(),
            cat: cat.to_string(),
            pid,
            tid,
            ts_us: ts,
            dur_us: dur,
            args: Vec::new(),
        });
        r
    }

    /// Fold another builder's spans into this domain's trace.
    pub fn merge_trace(&self, b: TraceBuilder) {
        self.trace.lock().unwrap().merge(b);
    }

    /// Take the collected spans out (leaves an empty builder behind).
    pub fn take_trace(&self) -> TraceBuilder {
        std::mem::take(&mut *self.trace.lock().unwrap())
    }

    pub fn export_chrome_json(&self) -> String {
        self.trace.lock().unwrap().to_chrome_json()
    }

    pub fn render_metrics(&self) -> String {
        self.registry.render()
    }

    /// Attach a time-series ring sampler (replaces any previous one).
    pub fn install_sampler(&self, s: RingSampler) {
        *self.sampler.lock().unwrap() = Some(s);
    }

    /// Push a snapshot into the installed sampler (no-op without one).
    pub fn sample(&self, t: f64, v: Vec<f64>) {
        if let Some(s) = self.sampler.lock().unwrap().as_mut() {
            s.push(t, v);
        }
    }

    /// `/timeseries.json` payload: the installed sampler's contents, or a
    /// valid empty document when no sampler is attached.
    pub fn export_timeseries_json(&self) -> String {
        match self.sampler.lock().unwrap().as_ref() {
            Some(s) => s.to_json(),
            None => RingSampler::new(0.0, 1, Vec::new()).to_json(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_uses_ceil_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 99.0), 99.0); // rank ceil(99.0) = 99 -> index 98
        assert_eq!(percentile(&v, 50.0), 50.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        assert_eq!(percentile(&v, 0.0), 1.0); // rank clamps to 1
    }

    #[test]
    fn percentile_small_samples_report_tail() {
        // ceil-rank gives the max for any p99 with n <= 100 (a truncating
        // `(len * 0.99) as usize` index drifts off the tail as n grows)
        let v = [1.0, 2.0];
        assert_eq!(percentile(&v, 99.0), 2.0);
        let v = [7.0];
        assert_eq!(percentile(&v, 99.0), 7.0);
        let ten: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(percentile(&ten, 99.0), 10.0);
        assert!(percentile(&[], 99.0).is_nan());
    }

    #[test]
    fn percentile_unsorted_sorts() {
        let mut v = [3.0, 1.0, 2.0];
        assert_eq!(percentile_unsorted(&mut v, 100.0), 3.0);
        assert_eq!(v[0], 1.0);
    }

    #[test]
    fn wall_span_records_only_when_tracing() {
        let off = Telemetry::disabled();
        off.wall_span(COMPILER_PID, 0, "pass", "m", || ());
        assert!(off.take_trace().is_empty());

        let on = Telemetry::new(true);
        let out = on.wall_span(COMPILER_PID, 0, "pass", "m", || 42);
        assert_eq!(out, 42);
        let tr = on.take_trace();
        assert_eq!(tr.len(), 1);
        assert_eq!(tr.events[0].name, "pass");
        assert!(tr.events[0].dur_us >= 0.0);
    }

    #[test]
    fn registry_is_always_live() {
        let t = Telemetry::disabled();
        t.registry.counter("c_total", "").inc();
        assert!(t.render_metrics().contains("c_total 1"));
    }

    #[test]
    fn concurrent_sampling_keeps_samples_intact() {
        use crate::telemetry::json::Json;
        // M producers race Telemetry::sample; the sampler mutex serializes
        // pushes, so every retained sample must be an untorn (tid, val)
        // pair and the stored timestamps must never run backwards
        let tel = Telemetry::disabled();
        tel.install_sampler(RingSampler::new(0.0, 64, vec!["tid".into(), "val".into()]));
        let producers = 4u64;
        let per = 100u64;
        let telref = &tel;
        std::thread::scope(|s| {
            for ti in 0..producers {
                s.spawn(move || {
                    for i in 0..per {
                        let t = (ti * per + i) as f64;
                        telref.sample(t, vec![ti as f64, (ti * 1000 + i) as f64]);
                    }
                });
            }
        });
        let doc = Json::parse(&tel.export_timeseries_json()).expect("valid JSON");
        let samples = doc.get("samples").and_then(Json::as_arr).unwrap();
        // the producer with the highest timestamps alone appends `per`
        // times, so the 64-slot ring is full and drops are accounted
        assert_eq!(samples.len(), 64);
        let dropped = doc.get("dropped").and_then(Json::as_f64).unwrap();
        assert!(dropped >= (per - 64) as f64, "dropped={dropped}");
        let mut prev = f64::MIN;
        for s in samples {
            let t = s.get("t").and_then(Json::as_f64).unwrap();
            assert!(t >= prev, "timestamps ran backwards: {t} after {prev}");
            prev = t;
            let v = s.get("v").and_then(Json::as_arr).unwrap();
            assert_eq!(v.len(), 2);
            let tid = v[0].as_f64().unwrap();
            let val = v[1].as_f64().unwrap();
            // untorn pair: val encodes (tid, i) with t = tid*per + i
            let i = val - tid * 1000.0;
            assert!((0.0..producers as f64).contains(&tid), "tid={tid}");
            assert!((0.0..per as f64).contains(&i), "val={val} tid={tid}");
        }
    }
}
