//! Ring-buffer time-series sampler.
//!
//! Snapshots a fixed set of series (queue depth, fps, per-cluster
//! utilization, per-component power, ...) at a configurable interval into
//! a bounded ring: old samples are dropped once `capacity` is reached,
//! and pushes closer together than `interval` coalesce into the last
//! slot (the newest value wins). Works in two time domains — simulated
//! cycles (`sim::sample_timeseries`) and wall-clock microseconds (the
//! live frame loop) — because it only ever sees `f64` timestamps.

use std::collections::VecDeque;

/// One snapshot: timestamp plus one value per series.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// Timestamp in the caller's domain (cycles or microseconds).
    pub t: f64,
    /// Values, index-aligned with [`RingSampler::series`].
    pub v: Vec<f64>,
}

/// Bounded time-series ring buffer.
#[derive(Debug)]
pub struct RingSampler {
    interval: f64,
    capacity: usize,
    series: Vec<String>,
    samples: VecDeque<Sample>,
    dropped: u64,
}

impl RingSampler {
    /// New sampler. `interval <= 0` disables coalescing; `capacity` is
    /// clamped to at least one slot.
    pub fn new(interval: f64, capacity: usize, series: Vec<String>) -> Self {
        RingSampler {
            interval,
            capacity: capacity.max(1),
            series,
            samples: VecDeque::new(),
            dropped: 0,
        }
    }

    /// Series names, index-aligned with every sample's value vector.
    pub fn series(&self) -> &[String] {
        &self.series
    }

    /// Number of samples currently held.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when no sample has been recorded (e.g. an empty run).
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Samples evicted by the ring so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Oldest-to-newest view of the retained samples.
    pub fn samples(&self) -> impl Iterator<Item = &Sample> {
        self.samples.iter()
    }

    /// Record a snapshot. A push closer than `interval` to the previous
    /// one coalesces: the newest values overwrite the last slot (its
    /// timestamp is kept so the grid stays on-interval). A push whose
    /// timestamp is *behind* the newest slot (a late arrival from a
    /// concurrent producer losing the race to the sampler lock) also
    /// coalesces, for any interval — the stored grid never runs backwards,
    /// so consumers can rely on non-decreasing timestamps.
    pub fn push(&mut self, t: f64, v: Vec<f64>) {
        debug_assert_eq!(v.len(), self.series.len());
        if let Some(last) = self.samples.back_mut() {
            if t < last.t || (self.interval > 0.0 && t - last.t < self.interval) {
                last.v = v;
                return;
            }
        }
        if self.samples.len() == self.capacity {
            self.samples.pop_front();
            self.dropped += 1;
        }
        self.samples.push_back(Sample { t, v });
    }

    /// Serialize as JSON (`/timeseries.json` payload). An empty sampler
    /// still produces a valid document.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(64 + self.samples.len() * 32);
        s.push_str(&format!("{{\n  \"interval\": {},\n  \"series\": [", self.interval));
        for (i, name) in self.series.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!("\"{}\"", name.replace('"', "\\\"")));
        }
        s.push_str("],\n  \"samples\": [");
        for (i, sm) in self.samples.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\n    {{\"t\": {}, \"v\": [", sm.t));
            for (j, v) in sm.v.iter().enumerate() {
                if j > 0 {
                    s.push_str(", ");
                }
                if v.is_finite() {
                    s.push_str(&format!("{v}"));
                } else {
                    s.push_str("null");
                }
            }
            s.push_str("]}");
        }
        if !self.samples.is_empty() {
            s.push_str("\n  ");
        }
        s.push_str(&format!("],\n  \"dropped\": {}\n}}\n", self.dropped));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::json::Json;

    fn names(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("s{i}")).collect()
    }

    #[test]
    fn ring_wraps_and_counts_drops() {
        let mut r = RingSampler::new(1.0, 3, names(1));
        for t in 0..5 {
            r.push(t as f64, vec![t as f64 * 10.0]);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        let ts: Vec<f64> = r.samples().map(|s| s.t).collect();
        assert_eq!(ts, [2.0, 3.0, 4.0]);
        assert_eq!(r.samples().last().unwrap().v, [40.0]);
    }

    #[test]
    fn pushes_within_interval_coalesce_keeping_grid_timestamp() {
        let mut r = RingSampler::new(10.0, 8, names(1));
        r.push(0.0, vec![1.0]);
        r.push(4.0, vec![2.0]);
        r.push(9.9, vec![3.0]);
        assert_eq!(r.len(), 1);
        let s = r.samples().next().unwrap();
        assert_eq!(s.t, 0.0);
        assert_eq!(s.v, [3.0]);
        r.push(10.0, vec![4.0]);
        assert_eq!(r.len(), 2);
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn zero_interval_never_coalesces() {
        let mut r = RingSampler::new(0.0, 8, names(1));
        r.push(1.0, vec![1.0]);
        r.push(1.0, vec![2.0]);
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn out_of_order_push_folds_into_newest_slot() {
        let mut r = RingSampler::new(0.0, 4, names(1));
        r.push(5.0, vec![1.0]);
        r.push(3.0, vec![2.0]); // late arrival: the grid cannot run backwards
        assert_eq!(r.len(), 1);
        let s = r.samples().next().unwrap().clone();
        assert_eq!(s.t, 5.0);
        assert_eq!(s.v, [2.0]);
        r.push(6.0, vec![3.0]);
        assert_eq!(r.len(), 2);
        assert_eq!(r.dropped(), 0);
        let ts: Vec<f64> = r.samples().map(|s| s.t).collect();
        assert_eq!(ts, [5.0, 6.0]);
    }

    #[test]
    fn empty_sampler_serializes_to_valid_json() {
        let r = RingSampler::new(2.5, 4, names(2));
        let text = r.to_json();
        let doc = Json::parse(&text).expect("valid JSON");
        assert_eq!(doc.get("interval").and_then(Json::as_f64), Some(2.5));
        assert_eq!(doc.get("samples").and_then(Json::as_arr).map(|a| a.len()), Some(0));
        assert_eq!(doc.get("dropped").and_then(Json::as_f64), Some(0.0));
    }

    #[test]
    fn json_round_trips_samples_and_drop_count() {
        let mut r = RingSampler::new(1.0, 2, vec!["fps".into(), "mw".into()]);
        r.push(0.0, vec![30.0, 47.5]);
        r.push(1.0, vec![29.0, 46.0]);
        r.push(2.0, vec![28.0, f64::NAN]);
        let doc = Json::parse(&r.to_json()).expect("valid JSON");
        let samples = doc.get("samples").and_then(Json::as_arr).unwrap();
        assert_eq!(samples.len(), 2);
        assert_eq!(samples[0].get("t").and_then(Json::as_f64), Some(1.0));
        let v = samples[1].get("v").and_then(Json::as_arr).unwrap();
        assert_eq!(v[0].as_f64(), Some(28.0));
        assert!(matches!(v[1], Json::Null));
        assert_eq!(doc.get("dropped").and_then(Json::as_f64), Some(1.0));
    }
}
