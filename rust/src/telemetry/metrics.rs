//! Lock-cheap metrics registry — counters, gauges and fixed-bucket
//! histograms with a Prometheus-style text exposition renderer.
//!
//! Design constraints (ROADMAP: heavy traffic, no external crates):
//!
//! - **Hot path is atomic-only.** Handles ([`Counter`], [`Gauge`],
//!   [`Histogram`]) are `Arc`s over atomics; `inc`/`set`/`observe` never
//!   take a lock. The registry mutex is touched only at registration and
//!   render time.
//! - **Fixed buckets.** Histograms use caller-supplied upper bounds plus an
//!   implicit `+Inf` bucket; exposition follows the Prometheus cumulative-
//!   bucket convention, so the output scrapes cleanly.
//! - **Offline.** The renderer returns a `String`; serving it over HTTP is
//!   the caller's business (`j3dai metrics` prints it to stdout).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::json;

/// Monotonically increasing counter.
#[derive(Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Monotonically increasing **float** counter (f64 bits, CAS-updated) —
/// for physical quantities that accumulate in fractional units, e.g.
/// millijoules of modeled energy. Counter semantics for Prometheus
/// (rendered with `# TYPE ... counter`).
#[derive(Clone)]
pub struct FCounter(Arc<AtomicU64>);

impl Default for FCounter {
    fn default() -> Self {
        FCounter(Arc::new(AtomicU64::new(0f64.to_bits())))
    }
}

impl FCounter {
    /// Add `v` (negative or non-finite increments are ignored — counters
    /// only go up).
    pub fn add(&self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            return;
        }
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + v).to_bits();
            match self.0.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Last-write-wins gauge (stored as f64 bits).
#[derive(Clone, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// A trace/span id pinned to a histogram observation — rendered in the
/// OpenMetrics exemplar syntax (`bucket 12 # {trace_id="..."} 0.067`) so a
/// tail-latency bucket links back to the span that caused it.
#[derive(Debug, Clone, PartialEq)]
pub struct Exemplar {
    /// The observed value the exemplar annotates.
    pub value: f64,
    /// Identifier of the span/trace that produced the observation.
    pub trace_id: String,
}

struct HistogramCore {
    /// Upper bounds of the finite buckets (ascending); the `+Inf` bucket is
    /// implicit as `counts.last()`.
    bounds: Vec<f64>,
    /// Per-bucket observation counts, len == bounds.len() + 1.
    counts: Vec<AtomicU64>,
    /// Exact running sum of observed values (f64 bits, CAS-updated).
    sum_bits: AtomicU64,
    count: AtomicU64,
    /// Largest value observed with an exemplar (f64 bits; starts at -inf).
    /// Read lock-free so non-record-setting observations skip the mutex.
    exemplar_max_bits: AtomicU64,
    /// The max-latency exemplar itself (locked only on a new maximum).
    exemplar: Mutex<Option<Exemplar>>,
}

/// Fixed-bucket histogram. `sum`/`count` are exact; bucket counts feed the
/// exposition and coarse percentile queries.
#[derive(Clone)]
pub struct Histogram(Arc<HistogramCore>);

impl Histogram {
    fn with_bounds(bounds: &[f64]) -> Self {
        let mut b = bounds.to_vec();
        b.sort_by(|a, x| a.partial_cmp(x).unwrap());
        let counts = (0..=b.len()).map(|_| AtomicU64::new(0)).collect();
        Histogram(Arc::new(HistogramCore {
            bounds: b,
            counts,
            sum_bits: AtomicU64::new(0f64.to_bits()),
            count: AtomicU64::new(0),
            exemplar_max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
            exemplar: Mutex::new(None),
        }))
    }

    pub fn observe(&self, v: f64) {
        let c = &self.0;
        let idx = c.bounds.iter().position(|b| v <= *b).unwrap_or(c.bounds.len());
        c.counts[idx].fetch_add(1, Ordering::Relaxed);
        c.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = c.sum_bits.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + v).to_bits();
            match c.sum_bits.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Observe `v` and keep `trace_id` as the exemplar if `v` is a new
    /// maximum. The fast path (not a record) is one extra atomic load on
    /// top of [`Histogram::observe`]; only record-setting observations
    /// take the exemplar lock.
    pub fn observe_with_exemplar(&self, v: f64, trace_id: &str) {
        self.observe(v);
        let c = &self.0;
        if v >= f64::from_bits(c.exemplar_max_bits.load(Ordering::Relaxed)) {
            let mut ex = c.exemplar.lock().unwrap();
            // re-check under the lock: a racing observer may have stored a
            // larger value between the load and the lock
            if ex.as_ref().is_none_or(|e| v >= e.value) {
                c.exemplar_max_bits.store(v.to_bits(), Ordering::Relaxed);
                *ex = Some(Exemplar { value: v, trace_id: trace_id.to_string() });
            }
        }
    }

    /// The current max-latency exemplar, if any observation carried one.
    pub fn exemplar(&self) -> Option<Exemplar> {
        self.0.exemplar.lock().unwrap().clone()
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }

    /// Exact mean of all observations (0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() / n as f64
        }
    }

    /// Coarse quantile from the bucket counts, nearest-rank with the same
    /// ceil-based rank as [`super::percentile`]: returns the **upper bound**
    /// of the bucket holding the ranked sample. `None` when the histogram
    /// is empty; a rank landing in the `+Inf` bucket reports the largest
    /// finite bound (a lower-bound estimate — callers needing the exact
    /// tail must keep raw samples).
    pub fn quantile(&self, p: f64) -> Option<f64> {
        let c = &self.0;
        let n = c.count.load(Ordering::Relaxed);
        if n == 0 {
            return None;
        }
        let rank = ((p / 100.0) * n as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, b) in c.bounds.iter().enumerate() {
            cum += c.counts[i].load(Ordering::Relaxed);
            if cum >= rank {
                return Some(*b);
            }
        }
        c.bounds.last().copied()
    }
}

enum Metric {
    Counter(Counter),
    FCounter(FCounter),
    Gauge(Gauge),
    Histogram(Histogram),
}

impl Metric {
    fn type_name(&self) -> &'static str {
        match self {
            Metric::Counter(_) | Metric::FCounter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

struct Entry {
    base: String,
    /// Rendered label pairs (`model="mbv1"`), empty when unlabeled.
    labels: String,
    help: String,
    metric: Metric,
}

/// The registry: name+labels -> metric. Get-or-create semantics so callers
/// can re-register the same series from any code path and share the handle.
#[derive(Default)]
pub struct Registry {
    entries: Mutex<BTreeMap<String, Entry>>,
}

fn label_str(labels: &[(&str, &str)]) -> String {
    labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", json::escape(v)))
        .collect::<Vec<_>>()
        .join(",")
}

/// `name{labels}` or bare `name`; `extra` appends one more pair (for `le`).
fn series(base: &str, labels: &str, extra: Option<&str>) -> String {
    let inner = match (labels.is_empty(), extra) {
        (true, None) => return base.to_string(),
        (true, Some(e)) => e.to_string(),
        (false, None) => labels.to_string(),
        (false, Some(e)) => format!("{labels},{e}"),
    };
    format!("{base}{{{inner}}}")
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str, help: &str) -> Counter {
        self.counter_with(name, &[], help)
    }

    pub fn counter_with(&self, name: &str, labels: &[(&str, &str)], help: &str) -> Counter {
        let ls = label_str(labels);
        let key = series(name, &ls, None);
        let mut m = self.entries.lock().unwrap();
        let e = m.entry(key).or_insert_with(|| Entry {
            base: name.to_string(),
            labels: ls,
            help: help.to_string(),
            metric: Metric::Counter(Counter::default()),
        });
        match &e.metric {
            Metric::Counter(c) => c.clone(),
            other => panic!("metric {name} already registered as {}", other.type_name()),
        }
    }

    pub fn fcounter(&self, name: &str, help: &str) -> FCounter {
        self.fcounter_with(name, &[], help)
    }

    pub fn fcounter_with(&self, name: &str, labels: &[(&str, &str)], help: &str) -> FCounter {
        let ls = label_str(labels);
        let key = series(name, &ls, None);
        let mut m = self.entries.lock().unwrap();
        let e = m.entry(key).or_insert_with(|| Entry {
            base: name.to_string(),
            labels: ls,
            help: help.to_string(),
            metric: Metric::FCounter(FCounter::default()),
        });
        match &e.metric {
            Metric::FCounter(c) => c.clone(),
            other => panic!("metric {name} already registered as {}", other.type_name()),
        }
    }

    pub fn gauge(&self, name: &str, help: &str) -> Gauge {
        self.gauge_with(name, &[], help)
    }

    pub fn gauge_with(&self, name: &str, labels: &[(&str, &str)], help: &str) -> Gauge {
        let ls = label_str(labels);
        let key = series(name, &ls, None);
        let mut m = self.entries.lock().unwrap();
        let e = m.entry(key).or_insert_with(|| Entry {
            base: name.to_string(),
            labels: ls,
            help: help.to_string(),
            metric: Metric::Gauge(Gauge::default()),
        });
        match &e.metric {
            Metric::Gauge(g) => g.clone(),
            other => panic!("metric {name} already registered as {}", other.type_name()),
        }
    }

    pub fn histogram(&self, name: &str, help: &str, bounds: &[f64]) -> Histogram {
        self.histogram_with(name, &[], help, bounds)
    }

    pub fn histogram_with(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        help: &str,
        bounds: &[f64],
    ) -> Histogram {
        let ls = label_str(labels);
        let key = series(name, &ls, None);
        let mut m = self.entries.lock().unwrap();
        let e = m.entry(key).or_insert_with(|| Entry {
            base: name.to_string(),
            labels: ls,
            help: help.to_string(),
            metric: Metric::Histogram(Histogram::with_bounds(bounds)),
        });
        match &e.metric {
            Metric::Histogram(h) => h.clone(),
            other => panic!("metric {name} already registered as {}", other.type_name()),
        }
    }

    /// Number of registered series (test/introspection hook).
    pub fn series_count(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// Render the Prometheus text exposition format (spec v0.0.4).
    pub fn render(&self) -> String {
        self.render_with_exemplars(false)
    }

    /// Like [`Registry::render`], optionally annotating each histogram's
    /// max-latency bucket with its exemplar in OpenMetrics syntax
    /// (`bucket 12 # {trace_id="frame41"} 48021`). Off by default so the
    /// plain text output stays bit-identical for v0.0.4 scrapers.
    pub fn render_with_exemplars(&self, exemplars: bool) -> String {
        let m = self.entries.lock().unwrap();
        let mut out = String::new();
        let mut last_base: Option<&str> = None;
        for e in m.values() {
            if last_base != Some(e.base.as_str()) {
                if !e.help.is_empty() {
                    out.push_str(&format!("# HELP {} {}\n", e.base, e.help));
                }
                out.push_str(&format!("# TYPE {} {}\n", e.base, e.metric.type_name()));
                last_base = Some(e.base.as_str());
            }
            match &e.metric {
                Metric::Counter(c) => {
                    out.push_str(&format!("{} {}\n", series(&e.base, &e.labels, None), c.get()));
                }
                Metric::FCounter(c) => {
                    out.push_str(&format!(
                        "{} {}\n",
                        series(&e.base, &e.labels, None),
                        json::fmt_f64(c.get())
                    ));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!(
                        "{} {}\n",
                        series(&e.base, &e.labels, None),
                        json::fmt_f64(g.get())
                    ));
                }
                Metric::Histogram(h) => {
                    let core = &h.0;
                    let ex = if exemplars { h.exemplar() } else { None };
                    let ex_idx = ex.as_ref().map(|x| {
                        core.bounds
                            .iter()
                            .position(|b| x.value <= *b)
                            .unwrap_or(core.bounds.len())
                    });
                    let ex_suffix = ex
                        .as_ref()
                        .map(|x| {
                            format!(
                                " # {{trace_id=\"{}\"}} {}",
                                json::escape(&x.trace_id),
                                json::fmt_f64(x.value)
                            )
                        })
                        .unwrap_or_default();
                    let bucket_base = format!("{}_bucket", e.base);
                    let mut cum = 0u64;
                    for (i, b) in core.bounds.iter().enumerate() {
                        cum += core.counts[i].load(Ordering::Relaxed);
                        let le = format!("le=\"{}\"", json::fmt_f64(*b));
                        let tail = if ex_idx == Some(i) { ex_suffix.as_str() } else { "" };
                        out.push_str(&format!(
                            "{} {}{}\n",
                            series(&bucket_base, &e.labels, Some(&le)),
                            cum,
                            tail
                        ));
                    }
                    cum += core.counts[core.bounds.len()].load(Ordering::Relaxed);
                    let tail =
                        if ex_idx == Some(core.bounds.len()) { ex_suffix.as_str() } else { "" };
                    out.push_str(&format!(
                        "{} {}{}\n",
                        series(&bucket_base, &e.labels, Some("le=\"+Inf\"")),
                        cum,
                        tail
                    ));
                    out.push_str(&format!(
                        "{} {}\n",
                        series(&format!("{}_sum", e.base), &e.labels, None),
                        json::fmt_f64(h.sum())
                    ));
                    out.push_str(&format!(
                        "{} {}\n",
                        series(&format!("{}_count", e.base), &e.labels, None),
                        h.count()
                    ));
                }
            }
        }
        out
    }
}

/// Parse Prometheus text exposition back into `series -> value` pairs
/// (`# HELP`/`# TYPE` lines are skipped). This is the consumer half of the
/// round-trip guarantee: whatever [`Registry::render`] emits — including
/// what the `/metrics` HTTP endpoint serves — re-parses to the same
/// numbers. Series names keep their label block verbatim
/// (`j3dai_frames_total{model="mbv1"}`).
pub fn parse_text(text: &str) -> crate::Result<BTreeMap<String, f64>> {
    let mut out = BTreeMap::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        // an OpenMetrics exemplar (` # {trace_id="..."} v`) annotates the
        // sample but is not part of its value — strip it before splitting
        let line = line.split_once(" # ").map_or(line, |(l, _)| l.trim_end());
        // value is the last whitespace-separated token; the series name is
        // everything before it (label values may contain escaped spaces
        // only inside quotes, which split-at-last-space handles)
        let (name, value) = line
            .rsplit_once(char::is_whitespace)
            .ok_or_else(|| anyhow::anyhow!("line {}: no value in {line:?}", ln + 1))?;
        let v: f64 = value
            .parse()
            .map_err(|e| anyhow::anyhow!("line {}: bad value {value:?}: {e}", ln + 1))?;
        out.insert(name.trim().to_string(), v);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let r = Registry::new();
        let c = r.counter("j3dai_frames_total", "frames");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // re-registration returns the same series
        assert_eq!(r.counter("j3dai_frames_total", "frames").get(), 5);
        let g = r.gauge("j3dai_queue_depth", "depth");
        g.set(2.0);
        assert_eq!(g.get(), 2.0);
    }

    #[test]
    fn histogram_buckets_cumulative() {
        let r = Registry::new();
        let h = r.histogram("svc_us", "service", &[10.0, 100.0]);
        for v in [5.0, 7.0, 50.0, 1000.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 1062.0).abs() < 1e-9);
        assert!((h.mean() - 265.5).abs() < 1e-9);
        let text = r.render();
        assert!(text.contains("# TYPE svc_us histogram"));
        assert!(text.contains("svc_us_bucket{le=\"10\"} 2"));
        assert!(text.contains("svc_us_bucket{le=\"100\"} 3"));
        assert!(text.contains("svc_us_bucket{le=\"+Inf\"} 4"));
        assert!(text.contains("svc_us_count 4"));
    }

    #[test]
    fn labels_render_inline() {
        let r = Registry::new();
        r.counter_with("frames_total", &[("model", "mbv1")], "frames").add(3);
        r.counter_with("frames_total", &[("model", "mbv2")], "frames").add(7);
        let text = r.render();
        assert!(text.contains("frames_total{model=\"mbv1\"} 3"));
        assert!(text.contains("frames_total{model=\"mbv2\"} 7"));
        // one TYPE header for the family
        assert_eq!(text.matches("# TYPE frames_total counter").count(), 1);
    }

    #[test]
    fn labeled_histogram_merges_le() {
        let r = Registry::new();
        let h = r.histogram_with("svc", &[("model", "x")], "", &[1.0]);
        h.observe(0.5);
        let text = r.render();
        assert!(text.contains("svc_bucket{model=\"x\",le=\"1\"} 1"), "{text}");
    }

    #[test]
    fn quantile_of_empty_histogram_is_none() {
        let r = Registry::new();
        let h = r.histogram("empty_us", "", &[1.0, 10.0]);
        assert_eq!(h.quantile(50.0), None);
        assert_eq!(h.quantile(99.0), None);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn quantile_single_sample_reports_its_bucket_for_any_p() {
        let r = Registry::new();
        let h = r.histogram("one_us", "", &[10.0, 100.0, 1000.0]);
        h.observe(42.0);
        // one sample in the le=100 bucket: every percentile maps to it
        assert_eq!(h.quantile(1.0), Some(100.0));
        assert_eq!(h.quantile(50.0), Some(100.0));
        assert_eq!(h.quantile(99.0), Some(100.0));
        // a sample past every bound degrades to the largest finite bound
        h.observe(5000.0);
        assert_eq!(h.quantile(99.0), Some(1000.0));
    }

    #[test]
    fn counter_increments_from_many_threads_lose_nothing() {
        let r = Registry::new();
        let c = r.counter("mt_total", "");
        let f = r.fcounter("mt_mj_total", "");
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let c = c.clone();
                let f = f.clone();
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        c.inc();
                        f.add(0.5);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.get(), 80_000);
        assert!((f.get() - 40_000.0).abs() < 1e-6, "f={}", f.get());
    }

    #[test]
    fn fcounter_ignores_negative_and_nonfinite() {
        let f = FCounter::default();
        f.add(1.5);
        f.add(-3.0);
        f.add(f64::NAN);
        f.add(f64::INFINITY);
        assert_eq!(f.get(), 1.5);
    }

    #[test]
    fn rendered_text_reparses_to_the_same_numbers() {
        let r = Registry::new();
        r.counter_with("frames_total", &[("model", "mbv1")], "frames").add(7);
        r.fcounter_with("energy_mj_total", &[("model", "mbv1")], "mJ").add(1.25);
        r.gauge("fps", "").set(29.5);
        let h = r.histogram("svc_us", "", &[10.0, 100.0]);
        for v in [5.0, 50.0, 500.0] {
            h.observe(v);
        }
        let parsed = parse_text(&r.render()).unwrap();
        assert_eq!(parsed["frames_total{model=\"mbv1\"}"], 7.0);
        assert_eq!(parsed["energy_mj_total{model=\"mbv1\"}"], 1.25);
        assert_eq!(parsed["fps"], 29.5);
        assert_eq!(parsed["svc_us_bucket{le=\"10\"}"], 1.0);
        assert_eq!(parsed["svc_us_bucket{le=\"100\"}"], 2.0);
        assert_eq!(parsed["svc_us_bucket{le=\"+Inf\"}"], 3.0);
        assert_eq!(parsed["svc_us_sum"], 555.0);
        assert_eq!(parsed["svc_us_count"], 3.0);
        // and rendering the parse input again is a fixed point
        assert_eq!(parse_text(&r.render()).unwrap(), parsed);
    }

    #[test]
    fn exemplar_tracks_the_maximum_observation() {
        let r = Registry::new();
        let h = r.histogram("svc_us", "", &[10.0, 100.0]);
        h.observe_with_exemplar(50.0, "frame0");
        h.observe_with_exemplar(7.0, "frame1"); // not a record — ignored
        let ex = h.exemplar().unwrap();
        assert_eq!(ex.trace_id, "frame0");
        assert_eq!(ex.value, 50.0);
        h.observe_with_exemplar(5000.0, "frame2"); // +Inf bucket record
        assert_eq!(h.exemplar().unwrap().trace_id, "frame2");
        // plain observations never disturb the exemplar
        h.observe(90_000.0);
        assert_eq!(h.exemplar().unwrap().trace_id, "frame2");
    }

    #[test]
    fn exemplars_render_behind_the_flag_only() {
        let r = Registry::new();
        let h = r.histogram("svc_us", "", &[10.0, 100.0]);
        h.observe_with_exemplar(50.0, "frame7");
        let plain = r.render();
        assert!(!plain.contains("trace_id"), "{plain}");
        let with = r.render_with_exemplars(true);
        let want = "svc_us_bucket{le=\"100\"} 1 # {trace_id=\"frame7\"} 50";
        assert!(with.contains(want), "{with}");
        // an over-the-top observation moves the exemplar to the +Inf line
        h.observe_with_exemplar(5000.0, "frame8");
        let with = r.render_with_exemplars(true);
        let want = "svc_us_bucket{le=\"+Inf\"} 2 # {trace_id=\"frame8\"} 5000";
        assert!(with.contains(want), "{with}");
        // the annotated text still re-parses to the same sample values
        let parsed = parse_text(&with).unwrap();
        assert_eq!(parsed["svc_us_bucket{le=\"+Inf\"}"], 2.0);
        assert_eq!(parsed["svc_us_count"], 2.0);
    }

    #[test]
    fn parse_text_rejects_garbage_values() {
        assert!(parse_text("metric_a notanumber").is_err());
        assert!(parse_text("loneword").is_err());
        assert!(parse_text("# just a comment\n").unwrap().is_empty());
    }

    #[test]
    fn render_is_deterministic() {
        let r = Registry::new();
        r.counter("b_total", "").inc();
        r.gauge("a_gauge", "").set(1.0);
        assert_eq!(r.render(), r.render());
        // BTreeMap ordering: a_gauge before b_total
        let text = r.render();
        assert!(text.find("a_gauge").unwrap() < text.find("b_total").unwrap());
    }
}
