//! Energy attribution — turns [`Activity`] event counts into joules and
//! publishes them as metrics, per model and per component.
//!
//! The span tracer knows *where the cycles go*; this module adds *where
//! the joules go*. Every [`crate::sim::InstrSpan`] carries the Activity
//! delta of exactly one instruction, so a span's energy is the
//! [`EnergyModel`] dot product over that delta ([`span_energy_pj`]); layer
//! and inference totals are the same product over the aggregated Activity
//! ([`EnergyBreakdown`]).
//!
//! **Attribution convention:** the controller/AGU/clock-tree component
//! (`pj_per_busy_cluster_cycle`) tracks the *compute-engine* timeline —
//! transfer spans carry zero busy cycles. Per-span/per-layer energies are
//! therefore an attribution view that can slightly under-count the
//! inference total whenever a cluster's transfer engine outruns its
//! compute engine (the cluster-level busy figure is `max(compute, xfer)`).
//! Totals published from the system-level Activity stay authoritative.
//! Static/leakage power is a chip-level property and is never attributed
//! to spans; it enters only through [`EnergyModel::power_mw`].

use super::metrics::{FCounter, Gauge, Registry};
use crate::power::{Activity, EnergyModel};

/// Energy-component labels, in the order [`EnergyBreakdown::components`]
/// reports them.
pub const COMPONENTS: [&str; 7] = ["mac", "sram", "dmpa", "dma", "tsv", "alu", "ctrl"];

/// One inference's energy split by architectural component, millijoules.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// PE MAC array.
    pub mac_mj: f64,
    /// NCB-local SRAM banks.
    pub sram_mj: f64,
    /// DMPA column connect (incl. its L2 accesses).
    pub dmpa_mj: f64,
    /// 64-bit system-interconnect DMA (incl. its L2 accesses).
    pub dma_mj: f64,
    /// HD-TSV crossings (adder on top of the L2 access).
    pub tsv_mj: f64,
    /// Elementwise ALU / NLU ops.
    pub alu_mj: f64,
    /// Controller + AGU/AIU + clock distribution (busy cluster-cycles).
    pub ctrl_mj: f64,
}

impl EnergyBreakdown {
    /// Split an Activity profile into per-component millijoules.
    pub fn from_activity(em: &EnergyModel, a: &Activity) -> Self {
        let mj = |pj_per: f64, n: u64| pj_per * n as f64 * 1e-9;
        EnergyBreakdown {
            mac_mj: mj(em.pj_per_mac, a.macs),
            sram_mj: mj(em.pj_per_sram_byte, a.local_sram_bytes),
            dmpa_mj: mj(em.pj_per_dmpa_byte, a.dmpa_bytes),
            dma_mj: mj(em.pj_per_dma_byte, a.dma_bytes),
            tsv_mj: mj(em.pj_per_tsv_byte, a.tsv_bytes),
            alu_mj: mj(em.pj_per_alu_op, a.alu_ops),
            ctrl_mj: mj(em.pj_per_busy_cluster_cycle, a.busy_cluster_cycles),
        }
    }

    /// Total dynamic energy, millijoules. Equals
    /// [`EnergyModel::inference_mj`] on the same Activity.
    pub fn total_mj(&self) -> f64 {
        self.mac_mj
            + self.sram_mj
            + self.dmpa_mj
            + self.dma_mj
            + self.tsv_mj
            + self.alu_mj
            + self.ctrl_mj
    }

    /// `(component label, mJ)` pairs, in [`COMPONENTS`] order.
    pub fn components(&self) -> [(&'static str, f64); 7] {
        [
            ("mac", self.mac_mj),
            ("sram", self.sram_mj),
            ("dmpa", self.dmpa_mj),
            ("dma", self.dma_mj),
            ("tsv", self.tsv_mj),
            ("alu", self.alu_mj),
            ("ctrl", self.ctrl_mj),
        ]
    }
}

/// Dynamic energy of one span/Activity delta in **picojoules** (the unit
/// Perfetto span args use — layer energies land in the 10^4..10^8 pJ range
/// where mJ would print as 0.000).
pub fn span_energy_pj(em: &EnergyModel, a: &Activity) -> f64 {
    EnergyBreakdown::from_activity(em, a).total_mj() * 1e9
}

/// Arithmetic intensity in MACs per byte of *off-cluster* traffic
/// (DMPA + DMA bytes — the roofline's bandwidth axis). Zero-traffic
/// activities report 0 rather than inf.
pub fn arithmetic_intensity(a: &Activity) -> f64 {
    let bytes = a.dmpa_bytes + a.dma_bytes;
    if bytes == 0 {
        return 0.0;
    }
    a.macs as f64 / bytes as f64
}

/// Handle bundle for one model's energy series in a [`Registry`]:
/// `j3dai_energy_mj_total`, per-component `j3dai_energy_component_mj_total`,
/// and the `j3dai_power_mw` / `j3dai_tops_per_watt` /
/// `j3dai_arith_intensity_macs_per_byte` gauges.
pub struct EnergyMetrics {
    total_mj: FCounter,
    components: Vec<(&'static str, FCounter)>,
    power_mw: Gauge,
    tops_per_watt: Gauge,
    intensity: Gauge,
}

impl EnergyMetrics {
    /// Get-or-create the energy series for `model`.
    pub fn register(reg: &Registry, model: &str) -> Self {
        let labels: &[(&str, &str)] = &[("model", model)];
        let total_mj = reg.fcounter_with(
            "j3dai_energy_mj_total",
            labels,
            "Modeled accelerator energy spent on inferences (mJ)",
        );
        let components = COMPONENTS
            .iter()
            .map(|c| {
                (
                    *c,
                    reg.fcounter_with(
                        "j3dai_energy_component_mj_total",
                        &[("model", model), ("component", c)],
                        "Modeled energy split by architectural component (mJ)",
                    ),
                )
            })
            .collect();
        EnergyMetrics {
            total_mj,
            components,
            power_mw: reg.gauge_with(
                "j3dai_power_mw",
                labels,
                "Modeled average accelerator power at the served frame rate (mW)",
            ),
            tops_per_watt: reg.gauge_with(
                "j3dai_tops_per_watt",
                labels,
                "Modeled power efficiency at the served frame rate (TOPS/W)",
            ),
            intensity: reg.gauge_with(
                "j3dai_arith_intensity_macs_per_byte",
                labels,
                "Arithmetic intensity of the model (MACs per off-cluster byte)",
            ),
        }
    }

    /// Account one completed inference: bump the energy counters and
    /// refresh the power/efficiency gauges at frame rate `fps`.
    pub fn record_inference(&self, em: &EnergyModel, a: &Activity, fps: f64) {
        let b = EnergyBreakdown::from_activity(em, a);
        self.total_mj.add(b.total_mj());
        for ((_, handle), (_, mj)) in self.components.iter().zip(b.components()) {
            handle.add(mj);
        }
        self.power_mw.set(em.power_mw(a, fps));
        self.tops_per_watt.set(em.tops_per_watt(a, fps));
        self.intensity.set(arithmetic_intensity(a));
    }

    /// Total mJ accounted so far (test/report hook).
    pub fn total_mj(&self) -> f64 {
        self.total_mj.get()
    }
}

/// Per-cluster energy series: `j3dai_energy_mj_total{cluster="i",...}`.
/// The same base name as the crate-wide total, split by a `cluster` label
/// — the labeled series sum back to the per-model total because the
/// cluster Activities partition the inference's event counts.
pub struct ClusterEnergyMetrics {
    per_cluster: Vec<FCounter>,
}

impl ClusterEnergyMetrics {
    /// Get-or-create one series per cluster for `model`.
    pub fn register(reg: &Registry, model: &str, clusters: usize) -> Self {
        let per_cluster = (0..clusters)
            .map(|ci| {
                let cl = ci.to_string();
                reg.fcounter_with(
                    "j3dai_energy_mj_total",
                    &[("cluster", cl.as_str()), ("model", model)],
                    "Modeled accelerator energy spent on inferences (mJ)",
                )
            })
            .collect();
        ClusterEnergyMetrics { per_cluster }
    }

    /// Account one inference from per-cluster Activity profiles
    /// (index-aligned with the registered clusters).
    pub fn record_inference(&self, em: &EnergyModel, per_cluster: &[Activity]) {
        for (handle, a) in self.per_cluster.iter().zip(per_cluster) {
            handle.add(EnergyBreakdown::from_activity(em, a).total_mj());
        }
    }

    /// Sum over all cluster series (test hook).
    pub fn total_mj(&self) -> f64 {
        self.per_cluster.iter().map(FCounter::get).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn activity() -> Activity {
        Activity {
            macs: 1_000_000,
            cycles: 10_000,
            local_sram_bytes: 400_000,
            dmpa_bytes: 50_000,
            dma_bytes: 2_000,
            tsv_bytes: 10_000,
            alu_ops: 30_000,
            busy_cluster_cycles: 60_000,
        }
    }

    #[test]
    fn breakdown_matches_inference_mj() {
        let em = EnergyModel::fdsoi28();
        let a = activity();
        let b = EnergyBreakdown::from_activity(&em, &a);
        assert!((b.total_mj() - em.inference_mj(&a)).abs() < 1e-12);
        assert!(b.components().iter().all(|(_, mj)| *mj > 0.0));
        assert!((span_energy_pj(&em, &a) - b.total_mj() * 1e9).abs() < 1e-3);
    }

    #[test]
    fn intensity_guards_zero_traffic() {
        assert_eq!(arithmetic_intensity(&Activity::default()), 0.0);
        let a = activity();
        let ai = arithmetic_intensity(&a);
        assert!((ai - 1_000_000.0 / 52_000.0).abs() < 1e-9);
    }

    #[test]
    fn metrics_accumulate_and_render() {
        let reg = Registry::new();
        let em = EnergyModel::fdsoi28();
        let a = activity();
        let m = EnergyMetrics::register(&reg, "mbv1");
        m.record_inference(&em, &a, 30.0);
        m.record_inference(&em, &a, 30.0);
        let per_frame = em.inference_mj(&a);
        assert!((m.total_mj() - 2.0 * per_frame).abs() < 1e-9);

        let text = reg.render();
        assert!(text.contains("j3dai_energy_mj_total{model=\"mbv1\"}"), "{text}");
        assert!(
            text.contains("j3dai_energy_component_mj_total{component=\"mac\",model=\"mbv1\"}")
                || text.contains("j3dai_energy_component_mj_total{model=\"mbv1\",component=\"mac\"}"),
            "{text}"
        );
        assert!(text.contains("j3dai_power_mw{model=\"mbv1\"}"));
        assert!(text.contains("j3dai_tops_per_watt{model=\"mbv1\"}"));
        // re-registering returns the same series
        let m2 = EnergyMetrics::register(&reg, "mbv1");
        assert_eq!(m2.total_mj(), m.total_mj());
    }

    #[test]
    fn cluster_series_partition_the_model_total() {
        let reg = Registry::new();
        let em = EnergyModel::fdsoi28();
        // two clusters splitting the inference's events evenly
        let mut half = activity();
        half.macs /= 2;
        half.local_sram_bytes /= 2;
        half.dmpa_bytes /= 2;
        half.dma_bytes /= 2;
        half.tsv_bytes /= 2;
        half.alu_ops /= 2;
        half.busy_cluster_cycles /= 2;
        let m = ClusterEnergyMetrics::register(&reg, "mbv1", 2);
        m.record_inference(&em, &[half, half]);
        assert!((m.total_mj() - em.inference_mj(&activity())).abs() < 1e-9);
        let text = reg.render();
        assert!(text.contains("j3dai_energy_mj_total{cluster=\"0\",model=\"mbv1\"}"), "{text}");
        assert!(text.contains("j3dai_energy_mj_total{cluster=\"1\",model=\"mbv1\"}"), "{text}");
    }
}
