//! Folded-stack flamegraph export.
//!
//! The span tracer already knows, for every simulated cycle, which layer,
//! cluster engine and instruction owned it. This module collapses those
//! spans into the folded text format consumed by inferno / flamegraph.pl:
//! one `frame;frame;frame weight` line per unique stack, here
//! `layer;cluster/engine;instruction` with the weight in cycles. Feed the
//! file to `inferno-flamegraph < profile.folded > profile.svg`.

use std::collections::BTreeMap;

/// Aggregated folded stacks: unique stack string -> total weight (cycles).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FoldedProfile {
    stacks: BTreeMap<String, u64>,
}

impl FoldedProfile {
    /// Empty profile.
    pub fn new() -> Self {
        FoldedProfile::default()
    }

    /// Add `weight` cycles to `stack` (frames already `;`-joined).
    /// Zero weights are dropped — inferno ignores them anyway.
    pub fn add(&mut self, stack: String, weight: u64) {
        if weight == 0 {
            return;
        }
        *self.stacks.entry(stack).or_insert(0) += weight;
    }

    /// Fold another profile in, prefixing every stack with `prefix;`
    /// (used to namespace per-model profiles in a multi-model run).
    pub fn merge_prefixed(&mut self, prefix: &str, o: &FoldedProfile) {
        for (stack, w) in &o.stacks {
            self.add(format!("{prefix};{stack}"), *w);
        }
    }

    /// Number of unique stacks.
    pub fn len(&self) -> usize {
        self.stacks.len()
    }

    /// True when no stack was recorded.
    pub fn is_empty(&self) -> bool {
        self.stacks.is_empty()
    }

    /// Sum of all weights.
    pub fn total_weight(&self) -> u64 {
        self.stacks.values().sum()
    }

    /// Iterate `(stack, weight)` in deterministic (sorted) order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.stacks.iter().map(|(s, w)| (s.as_str(), *w))
    }

    /// Render the inferno-compatible folded text: one `stack weight` line
    /// per unique stack, sorted for deterministic output.
    pub fn render(&self) -> String {
        let mut out = String::with_capacity(self.stacks.len() * 48);
        for (stack, w) in &self.stacks {
            out.push_str(stack);
            out.push(' ');
            out.push_str(&w.to_string());
            out.push('\n');
        }
        out
    }

    /// Parse folded text back (round-trip tests, external profiles).
    /// The weight is the token after the last space, as in flamegraph.pl.
    pub fn parse(text: &str) -> crate::Result<FoldedProfile> {
        let mut p = FoldedProfile::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim_end();
            if line.is_empty() {
                continue;
            }
            let (stack, w) = line
                .rsplit_once(' ')
                .ok_or_else(|| anyhow::anyhow!("line {}: no weight field", ln + 1))?;
            let w: u64 = w
                .parse()
                .map_err(|e| anyhow::anyhow!("line {}: bad weight {w:?}: {e}", ln + 1))?;
            p.add(stack.to_string(), w);
        }
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_aggregates_duplicate_stacks_and_drops_zeros() {
        let mut p = FoldedProfile::new();
        p.add("l0;cluster0/COMPUTE;conv.tile".into(), 10);
        p.add("l0;cluster0/COMPUTE;conv.tile".into(), 5);
        p.add("l0;cluster0/XFER;dmpa.load".into(), 0);
        assert_eq!(p.len(), 1);
        assert_eq!(p.total_weight(), 15);
    }

    #[test]
    fn render_parse_round_trip() {
        let mut p = FoldedProfile::new();
        p.add("mbv1/conv0;cluster0/COMPUTE;conv.tile".into(), 123);
        p.add("mbv1/conv0;cluster1/XFER;dmpa.load".into(), 45);
        p.add("host;host;dispatch".into(), 7);
        let text = p.render();
        assert_eq!(FoldedProfile::parse(&text).unwrap(), p);
        // every line is `frames... weight` with a numeric last token
        for line in text.lines() {
            let w = line.rsplit(' ').next().unwrap();
            assert!(w.parse::<u64>().unwrap() > 0);
        }
    }

    #[test]
    fn merge_prefixed_namespaces_stacks() {
        let mut a = FoldedProfile::new();
        a.add("l0;cluster0/COMPUTE;conv.tile".into(), 3);
        let mut all = FoldedProfile::new();
        all.merge_prefixed("mbv1_1_1", &a);
        assert_eq!(all.iter().next().unwrap(), ("mbv1_1_1;l0;cluster0/COMPUTE;conv.tile", 3));
    }

    #[test]
    fn parse_rejects_malformed_lines() {
        assert!(FoldedProfile::parse("no_weight_here").is_err());
        assert!(FoldedProfile::parse("stack notanumber").is_err());
        assert!(FoldedProfile::parse("").unwrap().is_empty());
    }
}
