//! PMU-style stall attribution for the cycle-level simulator.
//!
//! Real edge accelerators expose performance-monitoring counters that
//! classify every cycle a compute engine is *not* retiring work; the
//! co-design loop steers on exactly that breakdown (which transfer path
//! starves which layer). The sim engine reproduces the same visibility:
//! every cluster carries a [`PmuCounters`] bank and every non-busy
//! compute cycle is attributed to one [`StallReason`].
//!
//! The accounting invariant — checked by tests and rendered by
//! `report::render_stall_table` — is that per cluster
//! `busy + ctrl + sum(stalls) == total cycles`.

use std::collections::BTreeMap;

use super::metrics::{Counter, Registry};

/// Why a compute engine spent a cycle idle.
///
/// The first four reasons are attributed inside the cluster engine from
/// the transfer-timeline segment that covered the idle cycle; `HostSync`
/// is added at system level for cycles where a cluster finished early and
/// waited for the slowest cluster plus the host orchestration tail.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum StallReason {
    /// Waiting on a 64-bit DMA descriptor (base transfer time).
    DmaWait,
    /// Extra DMA cycles lost to bus arbitration against other clusters
    /// (the serialized-DMA penalty when the DMPA is disabled).
    NcbArb,
    /// DMPA setup beats: L2 bank/block conflict window before the
    /// 1024-bit stream reaches full rate.
    L2Bank,
    /// DMPA streaming beats refilling the NCB weight buffer (parameter
    /// refill dominates; activation spill shares the label).
    WeightRefill,
    /// Cluster finished its program and waited for the slowest cluster
    /// and the host orchestration tail.
    HostSync,
}

/// Number of stall reasons (array-bank width).
pub const N_STALL_REASONS: usize = 5;

/// All reasons, in `PmuBank::stalls` index order.
pub const STALL_REASONS: [StallReason; N_STALL_REASONS] = [
    StallReason::DmaWait,
    StallReason::NcbArb,
    StallReason::L2Bank,
    StallReason::WeightRefill,
    StallReason::HostSync,
];

impl StallReason {
    /// Index into a `stalls` array bank.
    pub fn index(self) -> usize {
        match self {
            StallReason::DmaWait => 0,
            StallReason::NcbArb => 1,
            StallReason::L2Bank => 2,
            StallReason::WeightRefill => 3,
            StallReason::HostSync => 4,
        }
    }

    /// Stable label used for metric series and report columns.
    pub fn label(self) -> &'static str {
        match self {
            StallReason::DmaWait => "dma_wait",
            StallReason::NcbArb => "ncb_arb",
            StallReason::L2Bank => "l2_bank",
            StallReason::WeightRefill => "weight_refill",
            StallReason::HostSync => "host_sync",
        }
    }
}

/// One counter bank: busy/control cycles plus one slot per stall reason.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PmuBank {
    /// Cycles the compute engine retired tile work.
    pub busy: u64,
    /// Cycles spent on control-flow instructions (AIU loop bookkeeping).
    pub ctrl: u64,
    /// Idle cycles per [`StallReason`] (index via `StallReason::index`).
    pub stalls: [u64; N_STALL_REASONS],
}

impl PmuBank {
    /// Add `cycles` to one stall slot.
    pub fn stall(&mut self, reason: StallReason, cycles: u64) {
        self.stalls[reason.index()] += cycles;
    }

    /// Cycles stalled for any reason.
    pub fn stall_total(&self) -> u64 {
        self.stalls.iter().sum()
    }

    /// Every cycle this bank accounts for.
    pub fn accounted(&self) -> u64 {
        self.busy + self.ctrl + self.stall_total()
    }

    /// Fold another bank into this one.
    pub fn merge(&mut self, o: &PmuBank) {
        self.busy += o.busy;
        self.ctrl += o.ctrl;
        for (s, v) in self.stalls.iter_mut().zip(o.stalls) {
            *s += v;
        }
    }
}

/// Per-cluster PMU state: a total bank plus one bank per layer id.
///
/// `HostSync` cycles are only folded into `total` (they happen after the
/// cluster program ended, so no layer owns them); every other event is
/// recorded in both `total` and the current layer's bank.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PmuCounters {
    /// Whole-run bank (includes system-level `HostSync`).
    pub total: PmuBank,
    /// Per-layer banks keyed by the `layer.mark` id active at the event.
    pub per_layer: BTreeMap<u32, PmuBank>,
}

impl PmuCounters {
    fn layer_bank(&mut self, layer: u32) -> &mut PmuBank {
        self.per_layer.entry(layer).or_default()
    }

    /// Record compute-busy cycles for `layer`.
    pub fn busy(&mut self, layer: u32, cycles: u64) {
        if cycles == 0 {
            return;
        }
        self.total.busy += cycles;
        self.layer_bank(layer).busy += cycles;
    }

    /// Record control-flow cycles for `layer`.
    pub fn ctrl(&mut self, layer: u32, cycles: u64) {
        if cycles == 0 {
            return;
        }
        self.total.ctrl += cycles;
        self.layer_bank(layer).ctrl += cycles;
    }

    /// Record stalled cycles for `layer`.
    pub fn stall(&mut self, layer: u32, reason: StallReason, cycles: u64) {
        if cycles == 0 {
            return;
        }
        self.total.stall(reason, cycles);
        self.layer_bank(layer).stall(reason, cycles);
    }
}

/// Prometheus-side view: `j3dai_stall_cycles_total{cluster,reason}`.
pub struct StallMetrics {
    per_cluster: Vec<[Counter; N_STALL_REASONS]>,
}

impl StallMetrics {
    /// Register one counter per (cluster, reason) pair.
    pub fn register(reg: &Registry, model: &str, clusters: usize) -> Self {
        let per_cluster = (0..clusters)
            .map(|ci| {
                let cl = ci.to_string();
                std::array::from_fn(|ri| {
                    reg.counter_with(
                        "j3dai_stall_cycles_total",
                        &[
                            ("cluster", cl.as_str()),
                            ("model", model),
                            ("reason", STALL_REASONS[ri].label()),
                        ],
                        "Simulated compute-idle cycles classified by stall reason",
                    )
                })
            })
            .collect();
        StallMetrics { per_cluster }
    }

    /// Add one inference's worth of stall cycles from per-cluster banks.
    pub fn record<'a>(&self, banks: impl IntoIterator<Item = &'a PmuCounters>) {
        for (counters, pmu) in self.per_cluster.iter().zip(banks) {
            for (c, v) in counters.iter().zip(pmu.total.stalls) {
                c.add(v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_accounting_adds_up() {
        let mut pmu = PmuCounters::default();
        pmu.busy(0, 100);
        pmu.ctrl(0, 3);
        pmu.stall(0, StallReason::DmaWait, 10);
        pmu.stall(1, StallReason::WeightRefill, 7);
        pmu.busy(1, 50);
        assert_eq!(pmu.total.accounted(), 170);
        let per: u64 = pmu.per_layer.values().map(PmuBank::accounted).sum();
        assert_eq!(per, pmu.total.accounted());
        assert_eq!(pmu.per_layer[&1].stalls[StallReason::WeightRefill.index()], 7);
    }

    #[test]
    fn zero_cycle_events_do_not_create_layer_banks() {
        let mut pmu = PmuCounters::default();
        pmu.busy(4, 0);
        pmu.stall(5, StallReason::L2Bank, 0);
        assert!(pmu.per_layer.is_empty());
        assert_eq!(pmu.total.accounted(), 0);
    }

    #[test]
    fn merge_folds_every_slot() {
        let mut a = PmuBank { busy: 1, ctrl: 2, stalls: [1, 2, 3, 4, 5] };
        let b = PmuBank { busy: 10, ctrl: 20, stalls: [5, 4, 3, 2, 1] };
        a.merge(&b);
        assert_eq!(a.busy, 11);
        assert_eq!(a.ctrl, 22);
        assert_eq!(a.stalls, [6; N_STALL_REASONS]);
        assert_eq!(a.accounted(), 63);
    }

    #[test]
    fn reason_labels_and_indices_are_consistent() {
        for (i, r) in STALL_REASONS.iter().enumerate() {
            assert_eq!(r.index(), i);
        }
        let labels: Vec<&str> = STALL_REASONS.iter().map(|r| r.label()).collect();
        assert_eq!(labels, ["dma_wait", "ncb_arb", "l2_bank", "weight_refill", "host_sync"]);
    }

    #[test]
    fn stall_metrics_publish_per_cluster_series() {
        let reg = Registry::new();
        let m = StallMetrics::register(&reg, "tiny", 2);
        let mut pmu0 = PmuCounters::default();
        pmu0.stall(0, StallReason::DmaWait, 42);
        let mut pmu1 = PmuCounters::default();
        pmu1.stall(0, StallReason::HostSync, 7);
        m.record([&pmu0, &pmu1]);
        let text = reg.render();
        let s0 = "j3dai_stall_cycles_total{cluster=\"0\",model=\"tiny\",reason=\"dma_wait\"} 42";
        let s1 = "j3dai_stall_cycles_total{cluster=\"1\",model=\"tiny\",reason=\"host_sync\"} 7";
        assert!(text.contains(s0), "{text}");
        assert!(text.contains(s1), "{text}");
    }
}
