//! Minimal JSON reader/writer helpers — just enough to emit and re-parse
//! the telemetry exports (Chrome trace-event files, `BENCH_telemetry.json`)
//! without a serde dependency (the offline registry has none).
//!
//! The writer side is string formatting in the exporters; this module owns
//! the shared escaping/number rules and a small recursive-descent parser
//! used by the round-trip tests and by `TraceBuilder::from_chrome_json`.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> crate::Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        anyhow::ensure!(p.i == p.b.len(), "trailing garbage at byte {}", p.i);
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// Escape a string for embedding between JSON double quotes.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Format an f64 as a JSON number token. Non-finite values (which JSON
/// cannot represent) degrade to 0; integral values print without the
/// fraction, everything else uses Rust's shortest round-trip form.
pub fn fmt_f64(v: f64) -> String {
    if !v.is_finite() {
        return "0".into();
    }
    if v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> crate::Result<()> {
        anyhow::ensure!(self.peek() == Some(c), "expected {:?} at byte {}", c as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> crate::Result<Json> {
        match self.peek() {
            Some(b'{') => self.obj(),
            Some(b'[') => self.arr(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.num(),
            None => anyhow::bail!("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> crate::Result<Json> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn num(&mut self) -> crate::Result<Json> {
        let start = self.i;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.i += 1;
        }
        let tok = std::str::from_utf8(&self.b[start..self.i]).unwrap_or("");
        tok.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| anyhow::anyhow!("bad number {tok:?} at byte {start}: {e}"))
    }

    fn string(&mut self) -> crate::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| anyhow::anyhow!("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'u' => {
                            anyhow::ensure!(self.i + 4 <= self.b.len(), "truncated \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| anyhow::anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| anyhow::anyhow!("bad \\u escape {hex}"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => anyhow::bail!("unknown escape \\{}", other as char),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // multi-byte UTF-8: re-sync on the full char
                    let s = std::str::from_utf8(&self.b[self.i - 1..])
                        .map_err(|_| anyhow::anyhow!("invalid utf-8 in string"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8() - 1;
                }
            }
        }
    }

    fn arr(&mut self) -> crate::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => anyhow::bail!("expected , or ] at byte {}", self.i),
            }
        }
    }

    fn obj(&mut self) -> crate::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => anyhow::bail!("expected , or }} at byte {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        let v = Json::parse(r#"{"a": 1, "b": [true, null, "x\n"], "c": -2.5e1}"#).unwrap();
        assert_eq!(v.get("a").and_then(Json::as_f64), Some(1.0));
        assert_eq!(v.get("c").and_then(Json::as_f64), Some(-25.0));
        let arr = v.get("b").and_then(Json::as_arr).unwrap();
        assert_eq!(arr[0], Json::Bool(true));
        assert_eq!(arr[1], Json::Null);
        assert_eq!(arr[2].as_str(), Some("x\n"));
    }

    #[test]
    fn escape_roundtrips() {
        let s = "quote\" slash\\ nl\n tab\t ctl\u{0001} ünïcode";
        let doc = format!("\"{}\"", escape(s));
        let v = Json::parse(&doc).unwrap();
        assert_eq!(v.as_str(), Some(s));
    }

    #[test]
    fn fmt_f64_rules() {
        assert_eq!(fmt_f64(2.0), "2");
        assert_eq!(fmt_f64(2.5), "2.5");
        assert_eq!(fmt_f64(f64::NAN), "0");
        // shortest-repr round-trips exactly
        let v = 123.456789;
        assert_eq!(fmt_f64(v).parse::<f64>().unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }
}
