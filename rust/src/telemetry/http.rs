//! Minimal blocking HTTP exporter — serves the live metrics registry as
//! Prometheus text at `/metrics` and the current span buffer as a Chrome
//! trace at `/trace.json`, from `std::net` only (the offline registry has
//! no hyper/tokio).
//!
//! One accept loop on a background thread, one request per connection
//! (`Connection: close`). This is scrape-grade, not serving-grade: a
//! Prometheus poll every few seconds and the occasional Perfetto snapshot,
//! while the frame loop keeps running — the hot path never touches the
//! listener. Start it with `j3dai serve --metrics-addr 127.0.0.1:9090`.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::Telemetry;

/// Routes served by the exporter (also the `/` index body).
const ROUTES: &str = "/metrics (Prometheus text)\n/trace.json (Chrome trace event JSON)\n/timeseries.json (ring-sampler time series)\n/healthz\n";

/// Handle to a running exporter; dropping it stops the accept loop.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9090`, port 0 for ephemeral) and serve
    /// `tel`'s registry and trace until [`MetricsServer::shutdown`]/drop.
    pub fn spawn(addr: &str, tel: Arc<Telemetry>) -> crate::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("cannot bind metrics endpoint {addr}: {e}"))?;
        // non-blocking accept so the loop can observe the stop flag without
        // needing a wake-up connection
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_seen = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("j3dai-metrics-http".into())
            .spawn(move || {
                while !stop_seen.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let _ = serve_connection(stream, &tel);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(MetricsServer { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join the thread (idempotent).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Read one request line, drain the headers, write one response.
fn serve_connection(stream: TcpStream, tel: &Telemetry) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let path = request_line.split_whitespace().nth(1).unwrap_or("/").to_string();
    // drain headers until the blank line (best effort — we never read a body)
    let mut header = String::new();
    loop {
        header.clear();
        match reader.read_line(&mut header) {
            Ok(0) | Err(_) => break,
            Ok(_) if header == "\r\n" || header == "\n" => break,
            Ok(_) => {}
        }
    }
    let (status, ctype, body) = route(&path, tel);
    respond(stream, status, ctype, &body)
}

fn route(path: &str, tel: &Telemetry) -> (&'static str, &'static str, String) {
    match path {
        "/metrics" => (
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            tel.render_metrics(),
        ),
        "/trace.json" => ("200 OK", "application/json", tel.export_chrome_json()),
        "/timeseries.json" => ("200 OK", "application/json", tel.export_timeseries_json()),
        "/healthz" => ("200 OK", "text/plain; charset=utf-8", "ok\n".to_string()),
        "/" => ("200 OK", "text/plain; charset=utf-8", ROUTES.to_string()),
        other => (
            "404 Not Found",
            "text/plain; charset=utf-8",
            format!("no route {other}; try:\n{ROUTES}"),
        ),
    }
}

fn respond(mut stream: TcpStream, status: &str, ctype: &str, body: &str) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;

    /// Plain-TcpStream HTTP GET against the exporter, returning
    /// (status line, body).
    pub fn get(addr: SocketAddr, path: &str) -> (String, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n").unwrap();
        let mut text = String::new();
        s.read_to_string(&mut text).unwrap();
        let status = text.lines().next().unwrap_or("").to_string();
        let body = text.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
        (status, body)
    }

    #[test]
    fn serves_metrics_trace_and_404() {
        let tel = Arc::new(Telemetry::new(true));
        tel.registry.counter("http_test_total", "").add(3);
        tel.record(crate::telemetry::TraceEvent {
            name: "probe".into(),
            cat: "test".into(),
            pid: 1,
            tid: 0,
            ts_us: 0.0,
            dur_us: 1.0,
            args: Vec::new(),
        });
        let mut srv = MetricsServer::spawn("127.0.0.1:0", Arc::clone(&tel)).unwrap();
        let addr = srv.addr();

        let (status, body) = get(addr, "/metrics");
        assert!(status.contains("200"), "{status}");
        assert!(body.contains("http_test_total 3"), "{body}");

        let (status, body) = get(addr, "/trace.json");
        assert!(status.contains("200"), "{status}");
        let doc = crate::telemetry::json::Json::parse(&body).unwrap();
        assert!(doc.get("traceEvents").is_some());

        // /timeseries.json is valid (empty) JSON before a sampler exists,
        // and serves the ring contents once one is installed
        let (status, body) = get(addr, "/timeseries.json");
        assert!(status.contains("200"), "{status}");
        assert!(crate::telemetry::json::Json::parse(&body).is_ok(), "{body}");
        tel.install_sampler(crate::telemetry::RingSampler::new(
            0.0,
            4,
            vec!["fps".into()],
        ));
        tel.sample(1.0, vec![30.0]);
        let (_, body) = get(addr, "/timeseries.json");
        let doc = crate::telemetry::json::Json::parse(&body).unwrap();
        let samples = doc.get("samples").and_then(crate::telemetry::json::Json::as_arr).unwrap();
        assert_eq!(samples.len(), 1);

        let (status, _) = get(addr, "/healthz");
        assert!(status.contains("200"));
        let (status, body) = get(addr, "/nope");
        assert!(status.contains("404"), "{status}");
        assert!(body.contains("/metrics"));

        srv.shutdown();
        // after shutdown the port stops accepting (bind may be reused, but
        // the old listener is gone — a fresh connect must fail or hang up)
        assert!(TcpStream::connect_timeout(&addr, Duration::from_millis(200)).is_err());
    }
}
