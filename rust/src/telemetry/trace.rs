//! Span/event tracing and the Chrome trace-event (Perfetto-loadable)
//! exporter.
//!
//! Every span is a complete ("ph":"X") event on a `(pid, tid)` track:
//!
//! - pid [`SIM_PID`] — *simulated* time: one COMPUTE and one XFER track per
//!   cluster, a `layers` track with one span per graph layer, and a `host`
//!   track for the serial orchestration tail. Timestamps are cycle counts
//!   converted to microseconds at the configured clock, so Perfetto's
//!   measurements read directly in accelerator time.
//! - pid [`COMPILER_PID`] — wall time of the compiler passes.
//! - pid [`FRAME_PID`] — wall time of the frame-loop service
//!   (capture / infer / record per frame).
//!
//! Open exports with <https://ui.perfetto.dev> ("Open trace file") or
//! `chrome://tracing`. See `docs/OBSERVABILITY.md` for the span hierarchy.

use super::json::{self, Json};

/// Process id for simulated-time tracks.
pub const SIM_PID: u32 = 1;
/// Process id for compiler-pass wall-time tracks.
pub const COMPILER_PID: u32 = 2;
/// Process id for frame-loop wall-time tracks.
pub const FRAME_PID: u32 = 3;

/// A span argument value (rendered into the event's `args` object).
#[derive(Debug, Clone, PartialEq)]
pub enum ArgValue {
    U64(u64),
    F64(f64),
    Str(String),
}

impl ArgValue {
    fn to_json(&self) -> String {
        match self {
            ArgValue::U64(v) => v.to_string(),
            ArgValue::F64(v) => json::fmt_f64(*v),
            ArgValue::Str(s) => format!("\"{}\"", json::escape(s)),
        }
    }

    /// Numeric view of the arg (integers widen to f64); None for strings.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            ArgValue::U64(v) => Some(*v as f64),
            ArgValue::F64(v) => Some(*v),
            ArgValue::Str(_) => None,
        }
    }
}

/// One complete span.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub name: String,
    /// Category; for sim instruction spans this is the owning layer's name.
    pub cat: String,
    pub pid: u32,
    pub tid: u32,
    pub ts_us: f64,
    pub dur_us: f64,
    /// Sorted by key on insertion (keeps the export canonical).
    pub args: Vec<(String, ArgValue)>,
}

/// Collects spans and track names; renders/parses the Chrome trace format.
#[derive(Debug, Clone, Default)]
pub struct TraceBuilder {
    pub events: Vec<TraceEvent>,
    thread_names: Vec<(u32, u32, String)>,
    process_names: Vec<(u32, String)>,
}

impl TraceBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, mut ev: TraceEvent) {
        ev.args.sort_by(|a, b| a.0.cmp(&b.0));
        self.events.push(ev);
    }

    /// Convenience constructor for a complete span.
    #[allow(clippy::too_many_arguments)]
    pub fn span(
        &mut self,
        pid: u32,
        tid: u32,
        name: &str,
        cat: &str,
        ts_us: f64,
        dur_us: f64,
        args: Vec<(String, ArgValue)>,
    ) {
        self.push(TraceEvent {
            name: name.to_string(),
            cat: cat.to_string(),
            pid,
            tid,
            ts_us,
            dur_us,
            args,
        });
    }

    pub fn name_thread(&mut self, pid: u32, tid: u32, label: &str) {
        if !self.thread_names.iter().any(|(p, t, _)| *p == pid && *t == tid) {
            self.thread_names.push((pid, tid, label.to_string()));
        }
    }

    pub fn name_process(&mut self, pid: u32, label: &str) {
        if !self.process_names.iter().any(|(p, _)| *p == pid) {
            self.process_names.push((pid, label.to_string()));
        }
    }

    /// Track label lookup (tests / report rendering).
    pub fn thread_label(&self, pid: u32, tid: u32) -> Option<&str> {
        self.thread_names
            .iter()
            .find(|(p, t, _)| *p == pid && *t == tid)
            .map(|(_, _, l)| l.as_str())
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Append another builder's spans and track names.
    pub fn merge(&mut self, other: TraceBuilder) {
        for (pid, label) in other.process_names {
            self.name_process(pid, &label);
        }
        for (pid, tid, label) in other.thread_names {
            self.name_thread(pid, tid, &label);
        }
        self.events.extend(other.events);
    }

    /// Re-home every track to `pid + delta` (used when several models share
    /// one export so their timelines don't interleave on one process row).
    pub fn shift_pid(&mut self, delta: u32) {
        for ev in &mut self.events {
            ev.pid += delta;
        }
        for n in &mut self.thread_names {
            n.0 += delta;
        }
        for n in &mut self.process_names {
            n.0 += delta;
        }
    }

    /// Render the Chrome trace-event JSON object format.
    pub fn to_chrome_json(&self) -> String {
        let mut s = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
        let mut first = true;
        let mut sep = |s: &mut String| {
            if first {
                first = false;
            } else {
                s.push(',');
            }
            s.push('\n');
        };
        for (pid, label) in &self.process_names {
            sep(&mut s);
            s.push_str(&format!(
                "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\"name\":\"process_name\",\"args\":{{\"name\":\"{}\"}}}}",
                json::escape(label)
            ));
        }
        for (pid, tid, label) in &self.thread_names {
            sep(&mut s);
            s.push_str(&format!(
                "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\"name\":\"thread_name\",\"args\":{{\"name\":\"{}\"}}}}",
                json::escape(label)
            ));
        }
        for ev in &self.events {
            sep(&mut s);
            let args = ev
                .args
                .iter()
                .map(|(k, v)| format!("\"{}\":{}", json::escape(k), v.to_json()))
                .collect::<Vec<_>>()
                .join(",");
            s.push_str(&format!(
                "{{\"ph\":\"X\",\"pid\":{},\"tid\":{},\"ts\":{},\"dur\":{},\"name\":\"{}\",\"cat\":\"{}\",\"args\":{{{args}}}}}",
                ev.pid,
                ev.tid,
                json::fmt_f64(ev.ts_us),
                json::fmt_f64(ev.dur_us),
                json::escape(&ev.name),
                json::escape(&ev.cat),
            ));
        }
        s.push_str("\n]}");
        s
    }

    /// Parse a Chrome trace-event export back (round-trip testing and
    /// offline analysis of saved traces). Numeric args whose value is a
    /// non-negative integer come back as [`ArgValue::U64`].
    pub fn from_chrome_json(text: &str) -> crate::Result<TraceBuilder> {
        let doc = Json::parse(text)?;
        let events = doc
            .get("traceEvents")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing traceEvents array"))?;
        let mut out = TraceBuilder::new();
        for ev in events {
            let ph = ev.get("ph").and_then(Json::as_str).unwrap_or("");
            let pid = ev.get("pid").and_then(Json::as_f64).unwrap_or(0.0) as u32;
            let tid = ev.get("tid").and_then(Json::as_f64).unwrap_or(0.0) as u32;
            let name = ev.get("name").and_then(Json::as_str).unwrap_or("").to_string();
            match ph {
                "M" => {
                    let label = ev
                        .get("args")
                        .and_then(|a| a.get("name"))
                        .and_then(Json::as_str)
                        .unwrap_or("");
                    if name == "thread_name" {
                        out.name_thread(pid, tid, label);
                    } else if name == "process_name" {
                        out.name_process(pid, label);
                    }
                }
                "X" => {
                    let mut args = Vec::new();
                    if let Some(Json::Obj(m)) = ev.get("args") {
                        for (k, v) in m {
                            let av = match v {
                                Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => {
                                    ArgValue::U64(*n as u64)
                                }
                                Json::Num(n) => ArgValue::F64(*n),
                                Json::Str(s) => ArgValue::Str(s.clone()),
                                _ => continue,
                            };
                            args.push((k.clone(), av));
                        }
                    }
                    out.push(TraceEvent {
                        name,
                        cat: ev.get("cat").and_then(Json::as_str).unwrap_or("").to_string(),
                        pid,
                        tid,
                        ts_us: ev.get("ts").and_then(Json::as_f64).unwrap_or(0.0),
                        dur_us: ev.get("dur").and_then(Json::as_f64).unwrap_or(0.0),
                        args,
                    });
                }
                _ => anyhow::bail!("unexpected event phase {ph:?}"),
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TraceBuilder {
        let mut b = TraceBuilder::new();
        b.name_process(SIM_PID, "sim:test");
        b.name_thread(SIM_PID, 0, "cluster0/COMPUTE");
        b.name_thread(SIM_PID, 1, "cluster0/XFER");
        b.span(
            SIM_PID,
            0,
            "conv.tile",
            "conv0",
            0.0,
            12.5,
            vec![("macs".into(), ArgValue::U64(4096))],
        );
        b.span(
            SIM_PID,
            1,
            "dmpa.load",
            "conv0",
            0.5,
            3.25,
            vec![
                ("bytes".into(), ArgValue::U64(1024)),
                ("note".into(), ArgValue::Str("weights \"w0\"".into())),
            ],
        );
        b
    }

    #[test]
    fn chrome_json_roundtrips() {
        let b = sample();
        let text = b.to_chrome_json();
        let back = TraceBuilder::from_chrome_json(&text).unwrap();
        assert_eq!(b.events, back.events);
        assert_eq!(back.thread_label(SIM_PID, 0), Some("cluster0/COMPUTE"));
        assert_eq!(back.thread_label(SIM_PID, 1), Some("cluster0/XFER"));
    }

    #[test]
    fn export_is_valid_json() {
        let text = sample().to_chrome_json();
        let doc = Json::parse(&text).unwrap();
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        // 1 process meta + 2 thread metas + 2 spans
        assert_eq!(events.len(), 5);
    }

    #[test]
    fn merge_and_shift() {
        let mut a = sample();
        let mut b = sample();
        b.shift_pid(10);
        assert_eq!(b.events[0].pid, SIM_PID + 10);
        a.merge(b);
        assert_eq!(a.len(), 4);
        assert!(a.thread_label(SIM_PID + 10, 0).is_some());
    }

    #[test]
    fn args_are_sorted_on_push() {
        let mut b = TraceBuilder::new();
        b.span(
            1,
            0,
            "x",
            "",
            0.0,
            1.0,
            vec![
                ("zz".into(), ArgValue::U64(1)),
                ("aa".into(), ArgValue::U64(2)),
            ],
        );
        assert_eq!(b.events[0].args[0].0, "aa");
    }
}
