//! Text assembler for the macro-op ISA — parses the mnemonic format that
//! [`super::Program::listing`] prints, so programs can be inspected,
//! hand-edited and reassembled (the workflow the paper's export gives its
//! users through the generated "assembly codes").

use super::{Instr, Program, Space};

fn parse_space(s: &str) -> crate::Result<Space> {
    match s {
        "L2Bottom" => Ok(Space::L2Bottom),
        "L2Middle" => Ok(Space::L2Middle),
        "Local" => Ok(Space::Local),
        _ => anyhow::bail!("unknown space {s}"),
    }
}

fn parse_num(s: &str) -> crate::Result<u32> {
    if let Some(hex) = s.strip_prefix("0x") {
        // an explicit 0x prefix is always hexadecimal — "0x1000" is 4096
        u32::from_str_radix(hex, 16).map_err(|e| anyhow::anyhow!("bad number {s}: {e}"))
    } else if s.chars().any(|c| c.is_ascii_alphabetic()) {
        u32::from_str_radix(s, 16).map_err(|e| anyhow::anyhow!("bad number {s}: {e}"))
    } else {
        s.parse().map_err(|e| anyhow::anyhow!("bad number {s}: {e}"))
    }
}

/// Parse an address token like `L2Bottom:0x1000` or `local:0x0`.
fn parse_addr(tok: &str) -> crate::Result<(Option<Space>, u32)> {
    let (sp, addr) = tok.split_once(':').ok_or_else(|| anyhow::anyhow!("bad address {tok}"))?;
    let space = if sp == "local" { None } else { Some(parse_space(sp)?) };
    Ok((space, parse_num(addr)?))
}

/// Parse one listing line (with or without the `NN:` prefix).
pub fn parse_line(line: &str) -> crate::Result<Option<Instr>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') || line.starts_with("//") {
        return Ok(None);
    }
    // strip "  123: " index prefix
    let body = match line.split_once(':') {
        Some((idx, rest)) if idx.trim().chars().all(|c| c.is_ascii_digit()) => rest.trim(),
        _ => line,
    };
    let toks: Vec<&str> = body.split_whitespace().collect();
    anyhow::ensure!(!toks.is_empty(), "empty instruction");
    let instr = match toks[0] {
        "dmpa.load" | "dma.load" => {
            // "dmpa.load  local:0x0 <- L2Bottom:0x1000 [4096B]"
            anyhow::ensure!(toks.len() >= 4, "malformed load: {body}");
            let (_, dst_addr) = parse_addr(toks[1])?;
            let (src_space, src_addr) = parse_addr(toks[3])?;
            let src = src_space.ok_or_else(|| anyhow::anyhow!("load source must be L2"))?;
            let bytes = parse_num(toks[4].trim_start_matches('[').trim_end_matches("B]"))?;
            if toks[0] == "dmpa.load" {
                Instr::DmpaLoad { src, src_addr, dst_addr, bytes }
            } else {
                Instr::DmaLoad { src, src_addr, dst_addr, bytes }
            }
        }
        "dmpa.store" | "dma.store" => {
            anyhow::ensure!(toks.len() >= 4, "malformed store: {body}");
            let (dst_space, dst_addr) = parse_addr(toks[1])?;
            let dst = dst_space.ok_or_else(|| anyhow::anyhow!("store dest must be L2"))?;
            let (_, src_addr) = parse_addr(toks[3])?;
            let bytes = parse_num(toks[4].trim_start_matches('[').trim_end_matches("B]"))?;
            if toks[0] == "dmpa.store" {
                Instr::DmpaStore { dst, dst_addr, src_addr, bytes }
            } else {
                Instr::DmaStore { dst, dst_addr, src_addr, bytes }
            }
        }
        "aiu.loop" => {
            // "aiu.loop   r0 count=12 stride=64"
            let reg: u8 = toks[1].trim_start_matches('r').parse()?;
            let count = parse_num(toks[2].trim_start_matches("count="))?;
            let stride = parse_num(toks[3].trim_start_matches("stride="))?;
            Instr::AiuLoop { reg, count, stride }
        }
        "route.cfg" => Instr::RouteCfg { pattern: toks[1].trim_start_matches("pattern=").parse()? },
        "conv.tile" => {
            // "conv.tile  64x64x64 first last"
            let dims: Vec<u32> = toks[1].split('x').map(|d| d.parse().unwrap_or(0)).collect();
            anyhow::ensure!(dims.len() == 3, "conv.tile needs MxKxN: {body}");
            Instr::ConvTile {
                m: dims[0],
                k: dims[1],
                n: dims[2],
                first: toks.contains(&"first"),
                last: toks.contains(&"last"),
            }
        }
        "dw.tile" => {
            let dims: Vec<u32> = toks[1].split('x').map(|d| d.parse().unwrap_or(0)).collect();
            let stride: u8 = toks[2].trim_start_matches('s').parse()?;
            Instr::DwTile { h: dims[0], w: dims[1], c: dims[2], stride }
        }
        "add.tile" => Instr::AddTile { n: parse_num(toks[1].trim_start_matches("n="))? },
        "act.tile" => Instr::ActTile { n: parse_num(toks[1].trim_start_matches("n="))?, nlu: toks.contains(&"nlu") },
        "pool.tile" => {
            let dims: Vec<u32> = toks[1].split('x').map(|d| d.parse().unwrap_or(0)).collect();
            Instr::PoolTile { h: dims[0], w: dims[1], c: dims[2] }
        }
        "layer.mark" => Instr::LayerMark { id: parse_num(toks[1].trim_start_matches("id="))? },
        "sync" => Instr::Sync,
        "halt" => Instr::Halt,
        other => anyhow::bail!("unknown mnemonic {other}"),
    };
    Ok(Some(instr))
}

/// Assemble a whole listing back into a [`Program`].
pub fn assemble_text(text: &str) -> crate::Result<Program> {
    let mut instrs = Vec::new();
    for (no, line) in text.lines().enumerate() {
        match parse_line(line) {
            Ok(Some(i)) => instrs.push(i),
            Ok(None) => {}
            Err(e) => anyhow::bail!("line {}: {e}", no + 1),
        }
    }
    Ok(Program { instrs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler;
    use crate::config::ArchConfig;
    use crate::graph::Shape;
    use crate::models;

    #[test]
    fn listing_roundtrips_through_assembler() {
        let g = models::tinycnn(Shape::new(24, 32, 3), 10);
        let c = compiler::compile(&g, &ArchConfig::j3dai()).unwrap();
        for prog in &c.cluster_programs {
            let text = prog.listing();
            let back = assemble_text(&text).unwrap();
            assert_eq!(prog.instrs, back.instrs);
        }
    }

    #[test]
    fn full_model_listing_roundtrips() {
        let g = models::paper_mbv2();
        let c = compiler::compile(&g, &ArchConfig::j3dai()).unwrap();
        let text = c.cluster_programs[0].listing();
        let back = assemble_text(&text).unwrap();
        assert_eq!(c.cluster_programs[0].instrs, back.instrs);
        // and the binary encoding agrees too
        assert_eq!(c.cluster_programs[0].assemble(), back.assemble());
    }

    #[test]
    fn comments_and_blanks_are_skipped() {
        let p = assemble_text("# header\n\n// note\nsync\nhalt\n").unwrap();
        assert_eq!(p.instrs, vec![Instr::Sync, Instr::Halt]);
    }

    #[test]
    fn hand_written_program_assembles() {
        let text = "
            aiu.loop r0 count=4 stride=64
            dmpa.load local:0x0 <- L2Bottom:0x1000 [4096B]
            sync
            conv.tile 64x27x32 first last
            dmpa.store L2Middle:0x2000 <- local:0x0 [2048B]
            halt
        ";
        let p = assemble_text(text).unwrap();
        assert_eq!(p.instrs.len(), 6);
        assert!(p.instrs[4].crosses_tsv());
        assert_eq!(p.total_macs(), 64 * 27 * 32);
    }

    #[test]
    fn bad_mnemonic_reports_line() {
        let err = assemble_text("sync\nfrobnicate x\n").unwrap_err();
        assert!(err.to_string().contains("line 2"));
    }
}
