//! Accelerator instruction set — the macro-op "assembly" the Aidge-analog
//! export emits and the cluster controllers execute.
//!
//! The paper's cluster is a SIMD machine: one controller fetches/decodes
//! and broadcasts control to 16 NCBs; the AGU generates multidimensional
//! addresses, the AIU drives routing from configurable hardware loops
//! ("no additional instructions are required to configure the routing"),
//! and the DMPA/CCONNECT moves 1024-bit columns between L2 and NCB SRAM.
//! We model the program at the granularity the controller actually
//! sequences: transfers, tile computations, routing configuration and
//! synchronization.
//!
//! Instructions encode to fixed 16-byte words (opcode + 3 u32 fields +
//! aux u16s) — the encoding exists so program *size* is measurable (the
//! AIU's program-memory-footprint claim is one of the paper's points).

pub mod asm;

use anyhow::Context as _;
use std::fmt;

/// AIU hardware loop registers per cluster controller (one per loop level
/// of the deepest mapped nest; `Instr::decode` rejects anything above).
pub const NUM_AIU_LOOP_REGS: u8 = 8;

/// Memory spaces addressable by transfers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Space {
    /// Global L2 memory (bottom-die partition).
    L2Bottom,
    /// Global L2 memory (middle-die partition, reached over TSVs).
    L2Middle,
    /// NCB-local multi-banked SRAM of this cluster.
    Local,
}

/// Which engine executes an instruction — the scheduler overlaps XFER with
/// COMPUTE (double buffering / "masking parameter loading", §III-C2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    Xfer,
    Compute,
    Control,
}

/// One macro-op.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Instr {
    /// Parallel column transfer through DMPA/CCONNECT (1024 b/cycle).
    DmpaLoad { src: Space, src_addr: u32, dst_addr: u32, bytes: u32 },
    DmpaStore { dst: Space, dst_addr: u32, src_addr: u32, bytes: u32 },
    /// Narrow transfer over the 64-bit system interconnect.
    DmaLoad { src: Space, src_addr: u32, dst_addr: u32, bytes: u32 },
    DmaStore { dst: Space, dst_addr: u32, src_addr: u32, bytes: u32 },
    /// Configure one AIU hardware loop (count/stride); loop register `reg`.
    AiuLoop { reg: u8, count: u32, stride: u32 },
    /// Explicit routing configuration (emitted only when the AIU is off —
    /// the ablation measures the cost the AIU removes).
    RouteCfg { pattern: u8 },
    /// GEMM tile on the MAC array: (m x k) activations times (k x n)
    /// weights, int32 accumulate, fused requant on the final k-slice.
    ConvTile { m: u32, k: u32, n: u32, first: bool, last: bool },
    /// Depthwise 3x3 tile over `h x w x c` with stride `s`.
    DwTile { h: u32, w: u32, c: u32, stride: u8 },
    /// Elementwise tiles on the PE ALU / NLU.
    AddTile { n: u32 },
    ActTile { n: u32, nlu: bool },
    PoolTile { h: u32, w: u32, c: u32 },
    /// Telemetry marker: all following instructions belong to graph layer
    /// `id`. Zero-cost on both engines; the traced simulator uses it to
    /// attribute per-instruction spans to layers (codegen emits one per
    /// layer per cluster).
    LayerMark { id: u32 },
    /// Barrier: wait until both engines of this cluster are idle.
    Sync,
    /// Signal the host (interrupt) and stop.
    Halt,
}

impl Instr {
    /// Which engine sequences this op.
    pub fn engine(&self) -> Engine {
        match self {
            Instr::DmpaLoad { .. }
            | Instr::DmpaStore { .. }
            | Instr::DmaLoad { .. }
            | Instr::DmaStore { .. } => Engine::Xfer,
            Instr::ConvTile { .. }
            | Instr::DwTile { .. }
            | Instr::AddTile { .. }
            | Instr::ActTile { .. }
            | Instr::PoolTile { .. } => Engine::Compute,
            Instr::AiuLoop { .. }
            | Instr::RouteCfg { .. }
            | Instr::LayerMark { .. }
            | Instr::Sync
            | Instr::Halt => Engine::Control,
        }
    }

    /// Bytes moved by transfer ops (0 for others).
    pub fn xfer_bytes(&self) -> u64 {
        match self {
            Instr::DmpaLoad { bytes, .. }
            | Instr::DmpaStore { bytes, .. }
            | Instr::DmaLoad { bytes, .. }
            | Instr::DmaStore { bytes, .. } => *bytes as u64,
            _ => 0,
        }
    }

    /// True if the transfer crosses the middle-die TSVs.
    pub fn crosses_tsv(&self) -> bool {
        matches!(
            self,
            Instr::DmpaLoad { src: Space::L2Middle, .. }
                | Instr::DmpaStore { dst: Space::L2Middle, .. }
                | Instr::DmaLoad { src: Space::L2Middle, .. }
                | Instr::DmaStore { dst: Space::L2Middle, .. }
        )
    }

    /// MACs performed by compute ops.
    pub fn macs(&self) -> u64 {
        match self {
            Instr::ConvTile { m, k, n, .. } => *m as u64 * *k as u64 * *n as u64,
            Instr::DwTile { h, w, c, .. } => 9 * *h as u64 * *w as u64 * *c as u64,
            _ => 0,
        }
    }

    /// Short mnemonic (also the traced simulator's span label).
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Instr::DmpaLoad { .. } => "dmpa.load",
            Instr::DmpaStore { .. } => "dmpa.store",
            Instr::DmaLoad { .. } => "dma.load",
            Instr::DmaStore { .. } => "dma.store",
            Instr::AiuLoop { .. } => "aiu.loop",
            Instr::RouteCfg { .. } => "route.cfg",
            Instr::ConvTile { .. } => "conv.tile",
            Instr::DwTile { .. } => "dw.tile",
            Instr::AddTile { .. } => "add.tile",
            Instr::ActTile { .. } => "act.tile",
            Instr::PoolTile { .. } => "pool.tile",
            Instr::LayerMark { .. } => "layer.mark",
            Instr::Sync => "sync",
            Instr::Halt => "halt",
        }
    }

    fn opcode(&self) -> u8 {
        match self {
            Instr::DmpaLoad { .. } => 0x01,
            Instr::DmpaStore { .. } => 0x02,
            Instr::DmaLoad { .. } => 0x03,
            Instr::DmaStore { .. } => 0x04,
            Instr::AiuLoop { .. } => 0x05,
            Instr::RouteCfg { .. } => 0x06,
            Instr::LayerMark { .. } => 0x07,
            Instr::ConvTile { .. } => 0x10,
            Instr::DwTile { .. } => 0x11,
            Instr::AddTile { .. } => 0x12,
            Instr::ActTile { .. } => 0x13,
            Instr::PoolTile { .. } => 0x14,
            Instr::Sync => 0x20,
            Instr::Halt => 0x21,
        }
    }

    /// Encode to the fixed 16-byte word.
    pub fn encode(&self) -> [u8; 16] {
        fn put(w: &mut [u8; 16], idx: usize, v: u32) {
            w[idx..idx + 4].copy_from_slice(&v.to_le_bytes());
        }
        let mut w = [0u8; 16];
        w[0] = self.opcode();
        match self {
            Instr::DmpaLoad { src, src_addr, dst_addr, bytes }
            | Instr::DmaLoad { src, src_addr, dst_addr, bytes } => {
                w[1] = space_code(*src);
                put(&mut w, 4, *src_addr);
                put(&mut w, 8, *dst_addr);
                put(&mut w, 12, *bytes);
            }
            Instr::DmpaStore { dst, dst_addr, src_addr, bytes }
            | Instr::DmaStore { dst, dst_addr, src_addr, bytes } => {
                w[1] = space_code(*dst);
                put(&mut w, 4, *dst_addr);
                put(&mut w, 8, *src_addr);
                put(&mut w, 12, *bytes);
            }
            Instr::AiuLoop { reg, count, stride } => {
                w[1] = *reg;
                put(&mut w, 4, *count);
                put(&mut w, 8, *stride);
            }
            Instr::RouteCfg { pattern } => w[1] = *pattern,
            Instr::LayerMark { id } => put(&mut w, 4, *id),
            Instr::ConvTile { m, k, n, first, last } => {
                w[1] = (*first as u8) | ((*last as u8) << 1);
                put(&mut w, 4, *m);
                put(&mut w, 8, *k);
                put(&mut w, 12, *n);
            }
            Instr::DwTile { h, w: ww, c, stride } => {
                w[1] = *stride;
                put(&mut w, 4, *h);
                put(&mut w, 8, *ww);
                put(&mut w, 12, *c);
            }
            Instr::AddTile { n } | Instr::ActTile { n, .. } => {
                if let Instr::ActTile { nlu, .. } = self {
                    w[1] = *nlu as u8;
                }
                put(&mut w, 4, *n);
            }
            Instr::PoolTile { h, w: ww, c } => {
                put(&mut w, 4, *h);
                put(&mut w, 8, *ww);
                put(&mut w, 12, *c);
            }
            Instr::Sync | Instr::Halt => {}
        }
        w
    }

    /// Decode from a 16-byte word, validating every discriminant: unknown
    /// opcodes, bad `Space` codes, out-of-range AIU loop registers and
    /// invalid flag bits are errors naming the offending byte offset.
    pub fn decode(w: &[u8; 16]) -> crate::Result<Instr> {
        let get = |idx: usize| u32::from_le_bytes(w[idx..idx + 4].try_into().unwrap());
        Ok(match w[0] {
            0x01 => Instr::DmpaLoad { src: code_space(w[1])?, src_addr: get(4), dst_addr: get(8), bytes: get(12) },
            0x02 => Instr::DmpaStore { dst: code_space(w[1])?, dst_addr: get(4), src_addr: get(8), bytes: get(12) },
            0x03 => Instr::DmaLoad { src: code_space(w[1])?, src_addr: get(4), dst_addr: get(8), bytes: get(12) },
            0x04 => Instr::DmaStore { dst: code_space(w[1])?, dst_addr: get(4), src_addr: get(8), bytes: get(12) },
            0x05 => {
                anyhow::ensure!(
                    w[1] < NUM_AIU_LOOP_REGS,
                    "AIU loop register {} out of range 0..{NUM_AIU_LOOP_REGS} at byte offset 1",
                    w[1]
                );
                Instr::AiuLoop { reg: w[1], count: get(4), stride: get(8) }
            }
            0x06 => Instr::RouteCfg { pattern: w[1] },
            0x07 => Instr::LayerMark { id: get(4) },
            0x10 => {
                anyhow::ensure!(
                    w[1] & !0b11 == 0,
                    "invalid ConvTile flag bits {:#04x} (only first|last allowed) at byte offset 1",
                    w[1]
                );
                Instr::ConvTile { m: get(4), k: get(8), n: get(12), first: w[1] & 1 != 0, last: w[1] & 2 != 0 }
            }
            0x11 => Instr::DwTile { h: get(4), w: get(8), c: get(12), stride: w[1] },
            0x12 => Instr::AddTile { n: get(4) },
            0x13 => {
                anyhow::ensure!(w[1] <= 1, "invalid ActTile nlu byte {:#04x} at byte offset 1", w[1]);
                Instr::ActTile { n: get(4), nlu: w[1] != 0 }
            }
            0x14 => Instr::PoolTile { h: get(4), w: get(8), c: get(12) },
            0x20 => Instr::Sync,
            0x21 => Instr::Halt,
            op => anyhow::bail!("unknown opcode {op:#04x} at byte offset 0"),
        })
    }
}

fn space_code(s: Space) -> u8 {
    match s {
        Space::L2Bottom => 0,
        Space::L2Middle => 1,
        Space::Local => 2,
    }
}

fn code_space(c: u8) -> crate::Result<Space> {
    Ok(match c {
        0 => Space::L2Bottom,
        1 => Space::L2Middle,
        2 => Space::Local,
        _ => anyhow::bail!("unknown space code {c} at byte offset 1"),
    })
}

impl fmt::Display for Instr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Instr::DmpaLoad { src, src_addr, dst_addr, bytes } => {
                write!(f, "dmpa.load  local:{dst_addr:#x} <- {src:?}:{src_addr:#x} [{bytes}B]")
            }
            Instr::DmpaStore { dst, dst_addr, src_addr, bytes } => {
                write!(f, "dmpa.store {dst:?}:{dst_addr:#x} <- local:{src_addr:#x} [{bytes}B]")
            }
            Instr::DmaLoad { src, src_addr, dst_addr, bytes } => {
                write!(f, "dma.load   local:{dst_addr:#x} <- {src:?}:{src_addr:#x} [{bytes}B]")
            }
            Instr::DmaStore { dst, dst_addr, src_addr, bytes } => {
                write!(f, "dma.store  {dst:?}:{dst_addr:#x} <- local:{src_addr:#x} [{bytes}B]")
            }
            Instr::AiuLoop { reg, count, stride } => write!(f, "aiu.loop   r{reg} count={count} stride={stride}"),
            Instr::RouteCfg { pattern } => write!(f, "route.cfg  pattern={pattern}"),
            Instr::ConvTile { m, k, n, first, last } => {
                write!(f, "conv.tile  {m}x{k}x{n}{}{}", if *first { " first" } else { "" }, if *last { " last" } else { "" })
            }
            Instr::DwTile { h, w, c, stride } => write!(f, "dw.tile    {h}x{w}x{c} s{stride}"),
            Instr::AddTile { n } => write!(f, "add.tile   n={n}"),
            Instr::ActTile { n, nlu } => write!(f, "act.tile   n={n}{}", if *nlu { " nlu" } else { "" }),
            Instr::PoolTile { h, w, c } => write!(f, "pool.tile  {h}x{w}x{c}"),
            Instr::LayerMark { id } => write!(f, "layer.mark id={id}"),
            Instr::Sync => write!(f, "sync"),
            Instr::Halt => write!(f, "halt"),
        }
    }
}

/// A per-cluster program plus its metadata.
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub instrs: Vec<Instr>,
}

impl Program {
    /// Encoded program size in bytes (the AIU footprint claim).
    pub fn size_bytes(&self) -> usize {
        self.instrs.len() * 16
    }

    pub fn total_macs(&self) -> u64 {
        self.instrs.iter().map(|i| i.macs()).sum()
    }

    /// Serialize to the 16-byte-word binary format.
    pub fn assemble(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.size_bytes());
        for i in &self.instrs {
            out.extend_from_slice(&i.encode());
        }
        out
    }

    /// Parse back from binary. Rejects inputs that are not a whole number
    /// of 16-byte words and any trailing bytes after the `halt` word —
    /// both are corruption, not padding.
    pub fn disassemble(bytes: &[u8]) -> crate::Result<Program> {
        anyhow::ensure!(
            bytes.len() % 16 == 0,
            "program length {} is not a multiple of the 16-byte instruction word ({} trailing bytes)",
            bytes.len(),
            bytes.len() % 16
        );
        let words = bytes.len() / 16;
        let mut instrs = Vec::with_capacity(words);
        for (wi, wdw) in bytes.chunks_exact(16).enumerate() {
            let instr = Instr::decode(wdw.try_into().unwrap())
                .with_context(|| format!("bad instruction at word {wi} (byte offset {})", wi * 16))?;
            let halted = instr == Instr::Halt;
            instrs.push(instr);
            if halted && wi + 1 < words {
                anyhow::bail!(
                    "{} trailing byte(s) after halt at word {wi} (byte offset {})",
                    bytes.len() - (wi + 1) * 16,
                    (wi + 1) * 16
                );
            }
        }
        Ok(Program { instrs })
    }

    /// Human-readable listing.
    pub fn listing(&self) -> String {
        self.instrs.iter().enumerate().map(|(i, op)| format!("{i:5}: {op}\n")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_program() -> Program {
        Program {
            instrs: vec![
                Instr::AiuLoop { reg: 0, count: 12, stride: 64 },
                Instr::DmpaLoad { src: Space::L2Bottom, src_addr: 0x1000, dst_addr: 0, bytes: 4096 },
                Instr::DmaLoad { src: Space::L2Middle, src_addr: 0x8000, dst_addr: 0x100, bytes: 64 },
                Instr::ConvTile { m: 64, k: 64, n: 64, first: true, last: false },
                Instr::ConvTile { m: 64, k: 64, n: 64, first: false, last: true },
                Instr::DwTile { h: 16, w: 16, c: 8, stride: 2 },
                Instr::AddTile { n: 1024 },
                Instr::ActTile { n: 512, nlu: true },
                Instr::PoolTile { h: 6, w: 8, c: 256 },
                Instr::DmpaStore { dst: Space::L2Bottom, dst_addr: 0x2000, src_addr: 0, bytes: 2048 },
                Instr::RouteCfg { pattern: 3 },
                Instr::Sync,
                Instr::Halt,
            ],
        }
    }

    #[test]
    fn roundtrip_encode_decode() {
        let p = sample_program();
        let bin = p.assemble();
        assert_eq!(bin.len(), p.size_bytes());
        let q = Program::disassemble(&bin).unwrap();
        assert_eq!(p.instrs, q.instrs);
    }

    #[test]
    fn engines_are_classified() {
        assert_eq!(Instr::Sync.engine(), Engine::Control);
        assert_eq!(Instr::AddTile { n: 1 }.engine(), Engine::Compute);
        assert_eq!(
            Instr::DmaStore { dst: Space::L2Bottom, dst_addr: 0, src_addr: 0, bytes: 1 }.engine(),
            Engine::Xfer
        );
    }

    #[test]
    fn mac_accounting() {
        let p = sample_program();
        assert_eq!(p.total_macs(), 2 * 64 * 64 * 64 + 9 * 16 * 16 * 8);
    }

    #[test]
    fn tsv_crossing_detection() {
        let i = Instr::DmaLoad { src: Space::L2Middle, src_addr: 0, dst_addr: 0, bytes: 8 };
        assert!(i.crosses_tsv());
        let i = Instr::DmpaLoad { src: Space::L2Bottom, src_addr: 0, dst_addr: 0, bytes: 8 };
        assert!(!i.crosses_tsv());
    }

    #[test]
    fn bad_opcode_rejected() {
        let mut w = [0u8; 16];
        w[0] = 0xFF;
        assert!(Instr::decode(&w).is_err());
    }

    #[test]
    fn listing_contains_mnemonics() {
        let l = sample_program().listing();
        assert!(l.contains("dmpa.load"));
        assert!(l.contains("conv.tile"));
        assert!(l.contains("halt"));
    }
}
