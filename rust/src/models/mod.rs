//! Model zoo — the paper's three workloads plus the tiny smoke-test net.
//!
//! Topology and naming mirror `python/compile/model.py` exactly (the layer
//! names seed the weight streams, so any divergence breaks the functional
//! cross-check). At full scale (alpha = 1, 256x192 / alpha = 1/2, 512x384)
//! the MAC counts must land on the paper's Table I values: 557 / 289 / 877
//! MMACs.

use crate::graph::{ch, Graph, Op, Shape, INPUT};

/// MobileNetV1 pointwise output channels per block (alpha = 1).
pub const MBV1_CH: [usize; 13] = [64, 128, 128, 256, 256, 512, 512, 512, 512, 512, 512, 1024, 1024];
/// MobileNetV1 depthwise strides per block.
pub const MBV1_STRIDE: [usize; 13] = [1, 2, 1, 2, 1, 2, 1, 1, 1, 1, 1, 2, 1];

/// MobileNetV2 inverted-residual config: (expansion, channels, repeats, stride).
pub const MBV2_CFG: [(usize, usize, usize, usize); 7] = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
];

/// FPN pyramid width at alpha = 1 (scaled like every other channel count).
/// 128 lands the alpha=0.5 512x384 network on the paper's 877 MMACs.
pub const FPN_CH: usize = 128;

fn conv(cout: usize, k: usize, stride: usize) -> Op {
    Op::Conv { kh: k, kw: k, cout, stride, relu: true }
}

fn conv_linear(cout: usize, k: usize) -> Op {
    Op::Conv { kh: k, kw: k, cout, stride: 1, relu: false }
}

/// MobileNetV1. `taps` = 1-based block indices whose pointwise output is
/// recorded (FPN backbone); returns (graph, tap layer indices).
pub fn mobilenet_v1_tapped(
    num: usize,
    den: usize,
    input: Shape,
    classes: usize,
    taps: &[usize],
) -> (Graph, Vec<usize>) {
    let p = format!("mbv1_{num}_{den}");
    let mut g = Graph::new(p.clone(), input);
    let mut x = g.push(format!("{p}/conv0"), conv(ch(32, num, den), 3, 2), vec![INPUT]);
    let mut tapped = Vec::new();
    for (i, (&c, &s)) in MBV1_CH.iter().zip(MBV1_STRIDE.iter()).enumerate() {
        let i = i + 1;
        x = g.push(format!("{p}/dw{i}"), Op::DwConv { stride: s }, vec![x]);
        x = g.push(format!("{p}/pw{i}"), conv(ch(c, num, den), 1, 1), vec![x]);
        if taps.contains(&i) {
            tapped.push(x);
        }
    }
    if taps.is_empty() {
        let ap = g.push(format!("{p}/avgpool"), Op::GlobalAvgPool, vec![x]);
        g.push(format!("{p}/fc"), Op::Dense { out: classes }, vec![ap]);
    }
    (g, tapped)
}

/// MobileNetV1 classifier.
pub fn mobilenet_v1(num: usize, den: usize, input: Shape, classes: usize) -> Graph {
    mobilenet_v1_tapped(num, den, input, classes, &[]).0
}

/// MobileNetV2 classifier.
pub fn mobilenet_v2(num: usize, den: usize, input: Shape, classes: usize) -> Graph {
    let p = format!("mbv2_{num}_{den}");
    let mut g = Graph::new(p.clone(), input);
    let mut x = g.push(format!("{p}/conv0"), conv(ch(32, num, den), 3, 2), vec![INPUT]);
    let mut cin = ch(32, num, den);
    let mut bi = 0;
    for (t, c, n, s) in MBV2_CFG {
        let cout = ch(c, num, den);
        for r in 0..n {
            bi += 1;
            let stride = if r == 0 { s } else { 1 };
            let inp = x;
            if t != 1 {
                x = g.push(format!("{p}/b{bi}/exp"), conv(cin * t, 1, 1), vec![x]);
            }
            x = g.push(format!("{p}/b{bi}/dw"), Op::DwConv { stride }, vec![x]);
            x = g.push(format!("{p}/b{bi}/proj"), conv_linear(cout, 1), vec![x]);
            if stride == 1 && cin == cout {
                x = g.push(format!("{p}/b{bi}/add"), Op::Add, vec![inp, x]);
            }
            cin = cout;
        }
    }
    x = g.push(format!("{p}/convlast"), conv(ch(1280, num, den), 1, 1), vec![x]);
    let ap = g.push(format!("{p}/avgpool"), Op::GlobalAvgPool, vec![x]);
    g.push(format!("{p}/fc"), Op::Dense { out: classes }, vec![ap]);
    g
}

/// FPN segmentation network over a MobileNetV1 backbone (paper: alpha=0.5,
/// 512x384 input, Cityscapes 19 classes, 877 MMACs). Taps: C3 = pw5
/// (stride 8), C4 = pw11 (stride 16), C5 = pw13 (stride 32).
pub fn fpn_seg(num: usize, den: usize, input: Shape, classes: usize) -> Graph {
    let (mut g, taps) = mobilenet_v1_tapped(num, den, input, 0, &[5, 11, 13]);
    let (c3, c4, c5) = (taps[0], taps[1], taps[2]);
    let p = format!("fpnseg_{num}_{den}");
    g.name = p.clone();
    let pc = ch(FPN_CH, num, den);
    let l5 = g.push(format!("{p}/fpn/lat5"), conv(pc, 1, 1), vec![c5]);
    let l4 = g.push(format!("{p}/fpn/lat4"), conv(pc, 1, 1), vec![c4]);
    let l3 = g.push(format!("{p}/fpn/lat3"), conv(pc, 1, 1), vec![c3]);
    let s4 = g.layers[l4].out_shape;
    let u5 = g.push(format!("{p}/fpn/up5"), Op::Upsample2x { to_h: s4.h, to_w: s4.w }, vec![l5]);
    let p4 = g.push(format!("{p}/fpn/add4"), Op::Add, vec![l4, u5]);
    let s3 = g.layers[l3].out_shape;
    let u4 = g.push(format!("{p}/fpn/up4"), Op::Upsample2x { to_h: s3.h, to_w: s3.w }, vec![p4]);
    let p3 = g.push(format!("{p}/fpn/add3"), Op::Add, vec![l3, u4]);
    let h1 = g.push(format!("{p}/fpn/head"), conv(pc, 3, 1), vec![p3]);
    let h2 = g.push(format!("{p}/fpn/head2"), conv(pc, 3, 1), vec![h1]);
    g.push(format!("{p}/fpn/cls"), conv_linear(classes, 1), vec![h2]);
    g
}

/// Tiny CNN (quickstart artifact).
pub fn tinycnn(input: Shape, classes: usize) -> Graph {
    let mut g = Graph::new("tinycnn", input);
    let c = g.push("tinycnn/conv0", conv(8, 3, 2), vec![INPUT]);
    let d = g.push("tinycnn/dw1", Op::DwConv { stride: 1 }, vec![c]);
    let p = g.push("tinycnn/pw1", conv(16, 1, 1), vec![d]);
    let a = g.push("tinycnn/avgpool", Op::GlobalAvgPool, vec![p]);
    g.push("tinycnn/fc", Op::Dense { out: classes }, vec![a]);
    g
}

/// The paper's Table I workloads at full scale.
pub fn paper_mbv1() -> Graph {
    mobilenet_v1(1, 1, Shape::new(192, 256, 3), 1000)
}

pub fn paper_mbv2() -> Graph {
    mobilenet_v2(1, 1, Shape::new(192, 256, 3), 1000)
}

pub fn paper_seg() -> Graph {
    fpn_seg(1, 2, Shape::new(384, 512, 3), 19)
}

/// The AOT artifact registry keys [`artifact_graph`] accepts (mirrors
/// `python/compile/model.py::MODELS`) — the CLI uses this to print a
/// helpful list on an unknown `--model`.
pub const ARTIFACT_NAMES: [&str; 4] =
    ["tinycnn_24x32", "mbv1_w25_48x64", "mbv2_w25_48x64", "fpnseg_w25_48x64"];

/// Reduced-scale builders matching the AOT artifact registry
/// (`python/compile/model.py::MODELS`).
pub fn artifact_graph(name: &str) -> Option<Graph> {
    match name {
        "tinycnn_24x32" => Some(tinycnn(Shape::new(24, 32, 3), 10)),
        "mbv1_w25_48x64" => Some(mobilenet_v1(1, 4, Shape::new(48, 64, 3), 100)),
        "mbv2_w25_48x64" => Some(mobilenet_v2(1, 4, Shape::new(48, 64, 3), 100)),
        "fpnseg_w25_48x64" => Some(fpn_seg(1, 4, Shape::new(48, 64, 3), 19)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names_all_resolve() {
        for n in ARTIFACT_NAMES {
            assert!(artifact_graph(n).is_some(), "{n}");
        }
        assert!(artifact_graph("nope").is_none());
    }

    #[test]
    fn paper_mbv1_mac_count() {
        // Table I: 557 MMACs at 256x192 (vs 569 at 224x224).
        let g = paper_mbv1();
        let mm = g.total_macs() as f64 / 1e6;
        assert!((mm - 557.0).abs() < 15.0, "MBv1 MMACs = {mm}");
        g.validate().unwrap();
    }

    #[test]
    fn paper_mbv2_mac_count() {
        // Table I: 289 MMACs at 256x192 (vs 300 at 224x224).
        let g = paper_mbv2();
        let mm = g.total_macs() as f64 / 1e6;
        assert!((mm - 289.0).abs() < 15.0, "MBv2 MMACs = {mm}");
        g.validate().unwrap();
    }

    #[test]
    fn paper_seg_mac_count() {
        // Table I: 877 MMACs at 512x384, alpha = 0.5 backbone.
        let g = paper_seg();
        let mm = g.total_macs() as f64 / 1e6;
        assert!((mm - 877.0).abs() < 45.0, "Seg MMACs = {mm}");
        g.validate().unwrap();
    }

    #[test]
    fn standard_mbv1_224_is_569m() {
        let g = mobilenet_v1(1, 1, Shape::new(224, 224, 3), 1000);
        let mm = g.total_macs() as f64 / 1e6;
        assert!((mm - 569.0).abs() < 15.0, "MBv1@224 MMACs = {mm}");
    }

    #[test]
    fn artifact_graphs_build_and_validate() {
        for name in ["tinycnn_24x32", "mbv1_w25_48x64", "mbv2_w25_48x64", "fpnseg_w25_48x64"] {
            let g = artifact_graph(name).unwrap();
            g.validate().unwrap();
            assert!(g.total_macs() > 0);
        }
        assert!(artifact_graph("nope").is_none());
    }

    #[test]
    fn mbv1_topology() {
        let g = paper_mbv1();
        // conv0 + 13*(dw+pw) + avgpool + fc
        assert_eq!(g.layers.len(), 1 + 26 + 2);
        assert_eq!(g.output(), Shape::new(1, 1, 1000));
        // strides reduce 256x192 by 32
        assert_eq!(g.layers[25].out_shape.h, 192 / 32);
    }

    #[test]
    fn mbv2_residual_count_matches_python() {
        // Twin of python test_mbv2_residual_condition (alpha = 1/4 -> 11).
        let g = mobilenet_v2(1, 4, Shape::new(48, 64, 3), 100);
        let adds = g.layers.iter().filter(|l| matches!(l.op, Op::Add)).count();
        assert_eq!(adds, 11);
        // alpha = 1 -> the canonical 10 residuals.
        let g = paper_mbv2();
        let adds = g.layers.iter().filter(|l| matches!(l.op, Op::Add)).count();
        assert_eq!(adds, 10);
    }

    #[test]
    fn fpn_output_is_stride8_classmap() {
        let g = paper_seg();
        assert_eq!(g.output(), Shape::new(384 / 8, 512 / 8, 19));
    }

    #[test]
    fn param_budget_fits_l2() {
        // The paper sized 5 MB L2 so "several networks that require multiple
        // MBs to store parameters" fit; MBv1 alpha=1 int8 is ~4.2 MB.
        let c = crate::config::ArchConfig::j3dai();
        assert!(paper_mbv1().total_param_bytes() < c.l2_bytes() as u64);
        assert!(paper_mbv2().total_param_bytes() < c.l2_bytes() as u64);
        assert!(paper_seg().total_param_bytes() < c.l2_bytes() as u64);
    }
}
