//! Activity-based energy model + die area model (28 nm FDSOI @ 0.85 V).
//!
//! We cannot run PrimePower on a post-P&R netlist here; instead the paper's
//! power numbers are reproduced by an event-energy model whose coefficients
//! were calibrated once against Table I (see EXPERIMENTS.md §Power):
//!
//! - Table I's @30 FPS vs @200 FPS rows pin the static power:
//!   P(fps) = E_inf * fps + P_static, giving E_inf(MBv1) ~= 1.43 mJ,
//!   E_inf(MBv2) ~= 0.92 mJ, P_static ~= 3-5 mW.
//! - E_inf decomposes into MAC energy + SRAM/L2/DMPA/DMA transport + TSV
//!   crossings + per-cycle controller overhead; the simulator supplies the
//!   event counts ([`Activity`]), this module supplies the joules.
//!
//! The *shape* claims that must hold: MBv2 costs more energy per MAC than
//! MBv1 (more data movement per MAC), the segmentation net sits in
//! between, and the J3DAI point wins GOPS/W/mm^2 in Table II.

pub mod area;

use crate::config::ArchConfig;

/// Event counts produced by one simulated inference.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Activity {
    /// Total MAC operations executed.
    pub macs: u64,
    /// Total cycles of the inference (critical path).
    pub cycles: u64,
    /// Bytes read/written in NCB-local SRAM (operand + result traffic).
    pub local_sram_bytes: u64,
    /// Bytes moved by the DMPA between L2 and clusters.
    pub dmpa_bytes: u64,
    /// Bytes moved by the 64-bit DMA.
    pub dma_bytes: u64,
    /// Bytes that crossed the middle-die TSVs.
    pub tsv_bytes: u64,
    /// Elementwise ALU/NLU operations (adds, activations, pool taps).
    pub alu_ops: u64,
    /// Cluster-cycles spent busy (for clock-gating modeling).
    pub busy_cluster_cycles: u64,
}

impl Activity {
    /// Sum every event counter except `cycles`.
    fn merge_events(&mut self, o: &Activity) {
        self.macs += o.macs;
        self.local_sram_bytes += o.local_sram_bytes;
        self.dmpa_bytes += o.dmpa_bytes;
        self.dma_bytes += o.dma_bytes;
        self.tsv_bytes += o.tsv_bytes;
        self.alu_ops += o.alu_ops;
        self.busy_cluster_cycles += o.busy_cluster_cycles;
    }

    /// Merge activity from a unit running *concurrently* with this one
    /// (clusters within one inference): event counts add, the critical
    /// path is the slower of the two.
    pub fn merge_parallel(&mut self, o: &Activity) {
        self.merge_events(o);
        self.cycles = self.cycles.max(o.cycles);
    }

    /// Merge activity from work running *after* this one (frame after
    /// frame, instruction after instruction): everything adds, cycles
    /// included. The old single `merge` used `max` for cycles, which
    /// silently under-reported sequential accumulation.
    pub fn merge_sequential(&mut self, o: &Activity) {
        self.merge_events(o);
        self.cycles += o.cycles;
    }
}

/// Energy coefficients (picojoules per event), 28 nm FDSOI @ 0.85 V.
///
/// Calibrated so the three Table I workloads land on the paper's measured
/// power within a few percent (EXPERIMENTS.md §Power shows the fit).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyModel {
    /// One 9-bit x 8-bit MAC incl. pipeline registers.
    pub pj_per_mac: f64,
    /// One byte read or written in an NCB SRAM bank.
    pub pj_per_sram_byte: f64,
    /// One byte through the DMPA column connect (incl. L2 access).
    pub pj_per_dmpa_byte: f64,
    /// One byte over the system interconnect DMA (incl. L2 access).
    pub pj_per_dma_byte: f64,
    /// One byte across the HD-TSV array (adder on top of the L2 access).
    pub pj_per_tsv_byte: f64,
    /// One elementwise ALU/NLU op.
    pub pj_per_alu_op: f64,
    /// Controller + AGU/AIU + clock distribution per busy cluster-cycle.
    pub pj_per_busy_cluster_cycle: f64,
    /// Static (leakage + always-on clock) power in mW.
    pub static_mw: f64,
}

impl EnergyModel {
    /// The calibrated 28 nm FDSOI / 0.85 V point. Fit against Table I's
    /// six power cells (three models x two frame rates) with the TSV/SRAM/
    /// DMPA transport costs pinned to plausible 28 nm values; residual
    /// error < 7% on every cell (EXPERIMENTS.md §Power).
    pub fn fdsoi28() -> Self {
        EnergyModel {
            pj_per_mac: 1.652,
            pj_per_sram_byte: 0.7,
            pj_per_dmpa_byte: 2.0,
            pj_per_dma_byte: 3.2,
            pj_per_tsv_byte: 0.6,
            pj_per_alu_op: 0.6,
            pj_per_busy_cluster_cycle: 76.4,
            static_mw: 3.8,
        }
    }

    /// Voltage-scaled variant (dynamic energy ~ V^2, leakage ~ V).
    pub fn at_voltage(&self, v: f64, vref: f64) -> Self {
        let s = (v / vref).powi(2);
        EnergyModel {
            pj_per_mac: self.pj_per_mac * s,
            pj_per_sram_byte: self.pj_per_sram_byte * s,
            pj_per_dmpa_byte: self.pj_per_dmpa_byte * s,
            pj_per_dma_byte: self.pj_per_dma_byte * s,
            pj_per_tsv_byte: self.pj_per_tsv_byte * s,
            pj_per_alu_op: self.pj_per_alu_op * s,
            pj_per_busy_cluster_cycle: self.pj_per_busy_cluster_cycle * s,
            static_mw: self.static_mw * (v / vref),
        }
    }

    /// Energy of one inference in millijoules.
    pub fn inference_mj(&self, a: &Activity) -> f64 {
        let pj = self.pj_per_mac * a.macs as f64
            + self.pj_per_sram_byte * a.local_sram_bytes as f64
            + self.pj_per_dmpa_byte * a.dmpa_bytes as f64
            + self.pj_per_dma_byte * a.dma_bytes as f64
            + self.pj_per_tsv_byte * a.tsv_bytes as f64
            + self.pj_per_alu_op * a.alu_ops as f64
            + self.pj_per_busy_cluster_cycle * a.busy_cluster_cycles as f64;
        pj * 1e-9
    }

    /// Average power in mW at a given frame rate. A non-positive or
    /// non-finite `fps` means "no frames": static power only, never
    /// a negative or NaN wattage.
    pub fn power_mw(&self, a: &Activity, fps: f64) -> f64 {
        if !fps.is_finite() || fps <= 0.0 {
            return self.static_mw;
        }
        self.inference_mj(a) * fps + self.static_mw
    }

    /// TOPS/W at a frame rate (1 MAC = 2 ops), the Table I metric.
    /// Zero when idle (`fps <= 0`) or when the power model degenerates to
    /// zero watts — never `inf`/NaN from a division by zero.
    pub fn tops_per_watt(&self, a: &Activity, fps: f64) -> f64 {
        if !fps.is_finite() || fps <= 0.0 {
            return 0.0;
        }
        let ops_per_s = a.macs as f64 * 2.0 * fps;
        let watts = self.power_mw(a, fps) * 1e-3;
        if watts <= 0.0 {
            return 0.0;
        }
        ops_per_s / watts / 1e12
    }
}

/// Latency of one inference in milliseconds at the configured clock.
pub fn latency_ms(cfg: &ArchConfig, cycles: u64) -> f64 {
    cycles as f64 / (cfg.freq_mhz * 1e3)
}

/// MAC/cycle efficiency — Table I/II's "MAC processing efficiency".
pub fn mac_efficiency(cfg: &ArchConfig, a: &Activity) -> f64 {
    a.macs as f64 / (a.cycles as f64 * cfg.macs_per_cycle() as f64)
}

/// Maximum sustainable FPS given the inference latency.
pub fn max_fps(cfg: &ArchConfig, cycles: u64) -> f64 {
    1e3 / latency_ms(cfg, cycles)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mbv1_like() -> Activity {
        // Roughly the event profile the simulator produces for MBv1@256x192.
        Activity {
            macs: 557_000_000,
            cycles: 992_000,
            local_sram_bytes: 180_000_000,
            dmpa_bytes: 9_000_000,
            dma_bytes: 300_000,
            tsv_bytes: 3_000_000,
            alu_ops: 3_000_000,
            busy_cluster_cycles: 5_500_000,
        }
    }

    #[test]
    fn power_scales_linearly_with_fps() {
        let em = EnergyModel::fdsoi28();
        let a = mbv1_like();
        let p30 = em.power_mw(&a, 30.0);
        let p200 = em.power_mw(&a, 200.0);
        let slope = (p200 - p30) / 170.0;
        let intercept = p30 - 30.0 * slope;
        assert!((intercept - em.static_mw).abs() < 1e-9);
    }

    #[test]
    fn efficiency_metric_matches_paper_definition() {
        let cfg = ArchConfig::j3dai();
        let a = mbv1_like();
        // 557e6 / (992000 * 768) = 73.1%
        let eff = mac_efficiency(&cfg, &a);
        assert!((eff - 0.731).abs() < 0.005, "eff={eff}");
    }

    #[test]
    fn voltage_scaling_is_quadratic() {
        let em = EnergyModel::fdsoi28();
        let low = em.at_voltage(0.6, 0.85);
        assert!((low.pj_per_mac / em.pj_per_mac - (0.6f64 / 0.85).powi(2)).abs() < 1e-12);
        assert!(low.static_mw < em.static_mw);
    }

    #[test]
    fn latency_and_fps() {
        let cfg = ArchConfig::j3dai();
        assert!((latency_ms(&cfg, 992_000) - 4.96).abs() < 1e-9);
        assert!((max_fps(&cfg, 992_000) - 201.6).abs() < 0.1);
    }

    #[test]
    fn merge_parallel_takes_critical_path() {
        let mut a = mbv1_like();
        let b = mbv1_like();
        a.merge_parallel(&b);
        assert_eq!(a.macs, 2 * 557_000_000);
        assert_eq!(a.cycles, 992_000); // max: concurrent clusters
        assert_eq!(a.busy_cluster_cycles, 2 * 5_500_000);
    }

    #[test]
    fn merge_sequential_accumulates_cycles() {
        let mut a = mbv1_like();
        let b = mbv1_like();
        a.merge_sequential(&b);
        assert_eq!(a.macs, 2 * 557_000_000);
        assert_eq!(a.cycles, 2 * 992_000); // sum: frame after frame
    }

    #[test]
    fn idle_fps_never_produces_inf_or_nan() {
        let em = EnergyModel::fdsoi28();
        let a = mbv1_like();
        for fps in [0.0, -1.0, f64::NAN, f64::NEG_INFINITY] {
            assert_eq!(em.power_mw(&a, fps), em.static_mw, "fps={fps}");
            assert_eq!(em.tops_per_watt(&a, fps), 0.0, "fps={fps}");
        }
        // even a zero-static model must not divide by zero
        let free = EnergyModel { static_mw: 0.0, ..em };
        let t = free.tops_per_watt(&Activity::default(), 30.0);
        assert!(t.is_finite(), "t={t}");
    }
}
