//! Die area / floorplan model — reproduces Fig. 5 (middle & bottom die
//! floorplans) and the chip-size rows of Table II / Fig. 6.
//!
//! The paper's geometry: chip 4.698 mm (H) x 3.438 mm (V) ~= 16 mm^2 per
//! die, 48 mm^2 for the 3-die stack; middle die = 6 mm^2 analog readout +
//! ISP/host/2 MB L2; bottom die = DNN accelerator + 3 MB L2.
//!
//! Component densities are 28 nm-plausible constants chosen so the
//! inventory fills the paper's floorplan; the *model* (inventory -> area ->
//! GOPS/W/mm^2 ranking) is what Table II exercises.

use crate::config::ArchConfig;

/// 28 nm SRAM density including periphery, mm^2 per KiB.
pub const SRAM_MM2_PER_KIB: f64 = 0.00195;
/// One PE (9b multiplier + 32b accumulator + ALU + NLU share), mm^2.
pub const PE_MM2: f64 = 0.0022;
/// Per-NCB overhead (local router, bank muxing, CCONNECT port), mm^2.
pub const NCB_OVERHEAD_MM2: f64 = 0.004;
/// Per-cluster overhead (controller, AGU, AIU, cluster router), mm^2.
pub const CLUSTER_OVERHEAD_MM2: f64 = 0.11;
/// DMA + system interconnect + sync registers, mm^2.
pub const SYSTEM_MM2: f64 = 0.55;
/// RISC-V host subsystem (CPU + 512 KB I/D memory), mm^2.
pub const HOST_MM2: f64 = 1.45;
/// ISP on the middle die, mm^2.
pub const ISP_MM2: f64 = 2.4;
/// High-speed interface + IO ring share per die, mm^2.
pub const IO_MM2: f64 = 1.1;

/// One named rectangle of the floorplan report.
#[derive(Debug, Clone)]
pub struct Region {
    pub name: &'static str,
    pub mm2: f64,
}

/// Area breakdown of one die.
#[derive(Debug, Clone)]
pub struct DiePlan {
    pub name: &'static str,
    pub regions: Vec<Region>,
    /// Physical die outline (paper: 4.698 x 3.438 mm).
    pub outline_mm2: f64,
}

impl DiePlan {
    pub fn used_mm2(&self) -> f64 {
        self.regions.iter().map(|r| r.mm2).sum()
    }

    pub fn utilization(&self) -> f64 {
        self.used_mm2() / self.outline_mm2
    }
}

/// Paper die outline in mm.
pub const DIE_H_MM: f64 = 4.698;
pub const DIE_V_MM: f64 = 3.438;

/// Bottom-die floorplan (Fig. 5b): DNN accelerator + 3 MB L2.
pub fn bottom_die(cfg: &ArchConfig) -> DiePlan {
    let ncbs = (cfg.clusters * cfg.ncbs_per_cluster) as f64;
    let pes = ncbs * cfg.pes_per_ncb as f64;
    let local_sram_kib = cfg.local_sram_bytes() as f64 / 1024.0;
    let l2_kib = cfg.l2_bottom_bytes as f64 / 1024.0;
    DiePlan {
        name: "bottom (AI die)",
        outline_mm2: DIE_H_MM * DIE_V_MM,
        regions: vec![
            Region { name: "PE arrays", mm2: pes * PE_MM2 },
            Region { name: "NCB SRAM", mm2: local_sram_kib * SRAM_MM2_PER_KIB },
            Region { name: "NCB routers/CCONNECT", mm2: ncbs * NCB_OVERHEAD_MM2 },
            Region { name: "cluster control (AGU/AIU)", mm2: cfg.clusters as f64 * CLUSTER_OVERHEAD_MM2 },
            Region { name: "L2 SRAM (3 MB)", mm2: l2_kib * SRAM_MM2_PER_KIB },
            Region { name: "DMA + interconnect", mm2: SYSTEM_MM2 },
            Region { name: "IO + TSV landing", mm2: IO_MM2 },
        ],
    }
}

/// Middle-die floorplan (Fig. 5a): analog readout, ISP, host, 2 MB L2.
pub fn middle_die(cfg: &ArchConfig) -> DiePlan {
    let l2_kib = cfg.l2_middle_bytes as f64 / 1024.0;
    DiePlan {
        name: "middle (sensor logic die)",
        outline_mm2: DIE_H_MM * DIE_V_MM,
        regions: vec![
            Region { name: "analog readout", mm2: 6.0 }, // paper-fixed
            Region { name: "ISP", mm2: ISP_MM2 },
            Region { name: "RISC-V host subsystem", mm2: HOST_MM2 },
            Region { name: "L2 SRAM (2 MB)", mm2: l2_kib * SRAM_MM2_PER_KIB },
            Region { name: "HSI + IO", mm2: IO_MM2 },
        ],
    }
}

/// A comparison-chip descriptor for Fig. 6 / Table II.
#[derive(Debug, Clone)]
pub struct ChipGeometry {
    pub label: &'static str,
    pub h_mm: f64,
    pub v_mm: f64,
    pub layers: usize,
    pub dnn_mem_mm2: f64,
}

impl ChipGeometry {
    pub fn area_mm2(&self) -> f64 {
        self.h_mm * self.v_mm
    }
}

/// The three chips of Fig. 6 (SONY values as reported in the paper).
pub fn fig6_chips() -> Vec<ChipGeometry> {
    vec![
        ChipGeometry { label: "SONY ISSCC'21 (2-layer)", h_mm: 7.558, v_mm: 8.206, layers: 2, dnn_mem_mm2: 31.0 },
        ChipGeometry { label: "SONY IEDM'24 (3-layer)", h_mm: 11.2, v_mm: 7.8, layers: 3, dnn_mem_mm2: 87.0 },
        ChipGeometry { label: "J3DAI (3-layer, this work)", h_mm: DIE_H_MM, v_mm: DIE_V_MM, layers: 3, dnn_mem_mm2: 16.0 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bottom_die_fits_outline() {
        let cfg = ArchConfig::j3dai();
        let plan = bottom_die(&cfg);
        let used = plan.used_mm2();
        assert!(used < plan.outline_mm2, "bottom die overflows: {used:.2} mm^2");
        // the accelerator + memory should dominate the die (>60% utilization)
        assert!(plan.utilization() > 0.6, "util={:.2}", plan.utilization());
    }

    #[test]
    fn middle_die_fits_outline_with_analog() {
        let cfg = ArchConfig::j3dai();
        let plan = middle_die(&cfg);
        assert!(plan.used_mm2() < plan.outline_mm2);
        assert!((plan.regions[0].mm2 - 6.0).abs() < 1e-12); // paper: 6 mm^2 analog
    }

    #[test]
    fn fig6_chip_areas_match_paper() {
        let chips = fig6_chips();
        assert!((chips[0].area_mm2() - 62.0).abs() < 0.1); // 7.558*8.206 = 62.02 per die; paper's 124 = 2 dies
        assert!((chips[1].area_mm2() - 87.36).abs() < 0.01);
        assert!((chips[2].area_mm2() - 16.15).abs() < 0.01);
        // stacked totals as the paper reports them
        assert!((chips[0].area_mm2() * chips[0].layers as f64 - 124.0).abs() < 0.5);
        assert!((chips[1].area_mm2() * chips[1].layers as f64 - 262.0).abs() < 0.5);
        assert!((chips[2].area_mm2() * chips[2].layers as f64 - 48.0).abs() < 0.5);
    }

    #[test]
    fn j3dai_is_most_compact() {
        let chips = fig6_chips();
        let j = &chips[2];
        for other in &chips[..2] {
            assert!(j.area_mm2() < other.area_mm2());
            assert!(j.dnn_mem_mm2 < other.dnn_mem_mm2);
        }
    }

    #[test]
    fn scaling_grows_bottom_die() {
        let small = bottom_die(&ArchConfig::scaled(2, 8, 8)).used_mm2();
        let big = bottom_die(&ArchConfig::scaled(8, 32, 8)).used_mm2();
        assert!(big > small);
    }
}
