//! Three-layer equivalence: for every AOT artifact, the Rust functional
//! simulator (PE integer semantics + PRNG weight streams) must produce the
//! same bytes as (a) the JAX golden output recorded at export time and
//! (b) the HLO executed live through PJRT. This is the core correctness
//! signal of the reproduction: L1 Pallas kernels == L2 JAX graph == L3
//! Rust PE model, bit for bit.

use j3dai::models;
use j3dai::runtime::{self, Runtime};
use j3dai::sim::functional::{self, Tensor};

fn artifacts_ready() -> bool {
    runtime::default_artifact_dir().join("manifest.txt").exists()
}

#[test]
fn functional_sim_matches_jax_golden_bytes() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let entries = runtime::load_manifest(&runtime::default_artifact_dir()).unwrap();
    assert!(entries.len() >= 4);
    for e in &entries {
        let g = models::artifact_graph(&e.name).expect("graph twin");
        let input = std::fs::read(&e.input_path).unwrap();
        let x = Tensor::new(e.input_shape, input);
        let y = functional::run_final(&g, &x);
        let golden = std::fs::read(&e.golden_path).unwrap();
        assert_eq!(y.data.len(), golden.len(), "{}: length", e.name);
        assert_eq!(y.data, golden, "{}: functional sim != JAX golden", e.name);
    }
}

#[test]
fn pjrt_execution_matches_golden_and_sim() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let dir = runtime::default_artifact_dir();
    let mut rt = Runtime::new().unwrap();
    let n = rt.load_all(&dir).unwrap();
    assert!(n >= 4, "expected >= 4 artifacts, got {n}");
    for e in runtime::load_manifest(&dir).unwrap() {
        let input = std::fs::read(&e.input_path).unwrap();
        let x = Tensor::new(e.input_shape, input);
        let out = rt.infer(&e.name, &x).unwrap();
        let golden = std::fs::read(&e.golden_path).unwrap();
        assert_eq!(out, golden, "{}: PJRT != JAX golden", e.name);

        // close the triangle: PJRT == Rust functional sim
        let g = models::artifact_graph(&e.name).unwrap();
        let y = functional::run_final(&g, &x);
        assert_eq!(out, y.data, "{}: PJRT != functional sim", e.name);
    }
}

#[test]
fn pjrt_rejects_wrong_input_shape() {
    if !artifacts_ready() {
        return;
    }
    let dir = runtime::default_artifact_dir();
    let mut rt = Runtime::new().unwrap();
    rt.load_all(&dir).unwrap();
    let bad = Tensor::new(j3dai::graph::Shape::new(8, 8, 3), vec![0; 192]);
    assert!(rt.infer("tinycnn_24x32", &bad).is_err());
}

#[test]
fn functional_sim_responds_to_input_changes() {
    // sanity against "golden passes because everything is constant"
    if !artifacts_ready() {
        return;
    }
    let e = &runtime::load_manifest(&runtime::default_artifact_dir()).unwrap()[0];
    let g = models::artifact_graph(&e.name).unwrap();
    let input = std::fs::read(&e.input_path).unwrap();
    let mut flipped = input.clone();
    for v in flipped.iter_mut() {
        *v = 255 - *v;
    }
    let y0 = functional::run_final(&g, &Tensor::new(e.input_shape, input));
    let y1 = functional::run_final(&g, &Tensor::new(e.input_shape, flipped));
    assert_ne!(y0.data, y1.data, "output insensitive to input");
}
