//! Tier-1 PPA regression gate: the three Table I workloads must stay
//! within tolerance of the paper, and `BENCH_ppa.json` (the `bench-ppa`
//! subcommand's output) must round-trip exactly those numbers.
//!
//! Tolerances mirror what the calibration demonstrably sustains
//! (EXPERIMENTS.md §Power, tests/pipeline_integration.rs): latency within
//! 5% (structural cycle model), power within 10% (fdsoi28 fit residual is
//! documented < 7% per cell; the gate leaves margin), MAC efficiency
//! within 5 percentage points, TOPS/W within 15% (it compounds the MAC and
//! power errors). Tightening these is a calibration task, not a test edit.

use j3dai::config::ArchConfig;
use j3dai::graph::Graph;
use j3dai::power::EnergyModel;
use j3dai::report;
use j3dai::telemetry::json;
use j3dai::{models, sim};

/// Table I as printed in the paper.
struct PaperRow {
    key: &'static str,
    mmacs: f64,
    latency_ms: f64,
    power_mw_30: f64,
    /// None where the paper prints "-" (latency cannot sustain 200 FPS).
    power_mw_200: Option<f64>,
    tops_per_w: f64,
    mac_eff: f64,
}

const TABLE1: [PaperRow; 3] = [
    PaperRow {
        key: "mbv1",
        mmacs: 557.0,
        latency_ms: 4.96,
        power_mw_30: 47.6,
        power_mw_200: Some(291.2),
        tops_per_w: 0.77,
        mac_eff: 0.768,
    },
    PaperRow {
        key: "mbv2",
        mmacs: 289.0,
        latency_ms: 4.04,
        power_mw_30: 30.5,
        power_mw_200: Some(186.7),
        tops_per_w: 0.62,
        mac_eff: 0.466,
    },
    PaperRow {
        key: "seg",
        mmacs: 877.0,
        latency_ms: 7.43,
        power_mw_30: 63.8,
        power_mw_200: None,
        tops_per_w: 0.82,
        mac_eff: 0.765,
    },
];

fn graph_for(key: &str) -> Graph {
    match key {
        "mbv1" => models::paper_mbv1(),
        "mbv2" => models::paper_mbv2(),
        "seg" => models::paper_seg(),
        other => panic!("no paper workload {other}"),
    }
}

#[track_caller]
fn assert_rel(got: f64, want: f64, tol: f64, what: &str) {
    assert!(
        (got - want).abs() / want <= tol,
        "{what}: got {got}, paper says {want} (tolerance {:.0}%)",
        tol * 100.0
    );
}

#[test]
fn table1_ppa_within_tolerance() {
    let cfg = ArchConfig::j3dai();
    let em = EnergyModel::fdsoi28();
    for row in &TABLE1 {
        let r = sim::simulate(&graph_for(row.key), &cfg).unwrap();
        let e = report::ppa_entry(&r, &em);
        assert_rel(e.mmacs, row.mmacs, 0.05, &format!("{} MMACs", row.key));
        assert_rel(e.latency_ms, row.latency_ms, 0.05, &format!("{} latency", row.key));
        assert_rel(
            e.power_mw_30.unwrap(),
            row.power_mw_30,
            0.10,
            &format!("{} power@30", row.key),
        );
        match row.power_mw_200 {
            Some(p200) => assert_rel(
                e.power_mw_200.unwrap(),
                p200,
                0.10,
                &format!("{} power@200", row.key),
            ),
            None => assert!(
                e.power_mw_200.is_none(),
                "{}: paper prints '-' at 200 FPS but the model sustains it",
                row.key
            ),
        }
        assert_rel(e.tops_per_w.unwrap(), row.tops_per_w, 0.15, &format!("{} TOPS/W", row.key));
        assert!(
            (e.mac_eff - row.mac_eff).abs() < 0.05,
            "{} MAC efficiency: got {}, paper {}",
            row.key,
            e.mac_eff,
            row.mac_eff
        );
        // energy is the power slope: P(fps) = E_inf * fps + P_static
        let slope_mj = (em.power_mw(&r.activity, 200.0) - em.power_mw(&r.activity, 30.0)) / 170.0;
        assert!((slope_mj - e.energy_mj).abs() < 1e-9, "{}", row.key);
    }
}

/// Satellite golden test: the calibrated fdsoi28 coefficients, fed the
/// simulator's Activity, reproduce the paper's measured power cells.
#[test]
fn fdsoi28_golden_reproduces_table1_power() {
    let cfg = ArchConfig::j3dai();
    let em = EnergyModel::fdsoi28();
    for row in &TABLE1 {
        let r = sim::simulate(&graph_for(row.key), &cfg).unwrap();
        let p30 = em.power_mw(&r.activity, 30.0);
        assert_rel(p30, row.power_mw_30, 0.075, &format!("{} golden power@30", row.key));
        if let Some(p200_paper) = row.power_mw_200 {
            let p200 = em.power_mw(&r.activity, 200.0);
            assert_rel(p200, p200_paper, 0.075, &format!("{} golden power@200", row.key));
        }
    }
}

#[test]
fn bench_ppa_json_gates_and_round_trips() {
    let cfg = ArchConfig::j3dai();
    let em = EnergyModel::fdsoi28();
    let entries: Vec<report::PpaEntry> = TABLE1
        .iter()
        .map(|row| {
            report::ppa_entry(&sim::simulate(&graph_for(row.key), &cfg).unwrap(), &em)
        })
        .collect();
    let text = report::bench_ppa_json(&cfg, &entries);
    let doc = json::Json::parse(&text).unwrap();

    let arch = doc.get("arch").expect("arch header");
    assert_eq!(arch.get("macs_per_cycle").and_then(json::Json::as_f64), Some(768.0));
    assert_eq!(arch.get("peak_gops").and_then(json::Json::as_f64), Some(307.2));
    assert!(arch.get("die_mm2").and_then(json::Json::as_f64).unwrap() > 0.0);

    let rows = doc.get("models").and_then(json::Json::as_arr).expect("models array");
    assert_eq!(rows.len(), TABLE1.len());
    for (row, j) in TABLE1.iter().zip(rows) {
        let f = |k: &str| j.get(k).and_then(json::Json::as_f64).unwrap();
        assert_rel(f("latency_ms"), row.latency_ms, 0.05, &format!("{} json latency", row.key));
        assert_rel(f("power_mw_30"), row.power_mw_30, 0.10, &format!("{} json power", row.key));
        assert!(f("energy_mj") > 0.0);
        if row.power_mw_200.is_none() {
            // a "-" cell must serialize as JSON null, never as 0
            assert_eq!(j.get("power_mw_200"), Some(&json::Json::Null), "{}", row.key);
        }
    }
}
