//! Observability integration: PMU stall attribution accounts for every
//! simulated cycle, the folded flamegraph export is inferno-loadable, the
//! time-series sampler and the frame loop's per-cluster series work end to
//! end, and `bench-compare` gates regressions with a non-zero exit.

use j3dai::config::ArchConfig;
use j3dai::coordinator::{run_functional_loop, CoordinatorConfig};
use j3dai::graph::Shape;
use j3dai::models;
use j3dai::sim;
use j3dai::telemetry::json::Json;
use j3dai::telemetry::{PmuBank, StallReason, Telemetry};

fn paper_workloads() -> [j3dai::graph::Graph; 3] {
    [models::paper_mbv1(), models::paper_mbv2(), models::paper_seg()]
}

#[test]
fn stall_attribution_accounts_for_every_cycle() {
    // the acceptance bar: on all three Table I workloads, every cluster's
    // busy + ctrl + classified stalls equals the end-to-end cycle count
    let cfg = ArchConfig::j3dai();
    for g in paper_workloads() {
        let r = sim::simulate(&g, &cfg).unwrap();
        assert!(!r.clusters.is_empty(), "{}: no cluster runs", g.name);
        for (ci, c) in r.clusters.iter().enumerate() {
            assert_eq!(
                c.pmu.total.accounted(),
                r.cycles,
                "{} cluster {ci}: busy {} + ctrl {} + stalls {} != {} cycles",
                g.name,
                c.pmu.total.busy,
                c.pmu.total.ctrl,
                c.pmu.total.stall_total(),
                r.cycles
            );
            // the per-layer banks decompose everything except the
            // system-level HostSync wait (no layer owns the post-halt idle)
            let per_layer: u64 = c.pmu.per_layer.values().map(PmuBank::accounted).sum();
            let host_sync = c.pmu.total.stalls[StallReason::HostSync.index()];
            assert_eq!(per_layer + host_sync, r.cycles, "{} cluster {ci}", g.name);
        }
    }
}

#[test]
fn traced_and_untraced_pmu_counters_agree() {
    let cfg = ArchConfig::j3dai();
    let g = models::paper_mbv1();
    let plain = sim::simulate(&g, &cfg).unwrap();
    let (traced, tr) = sim::simulate_traced(&g, &cfg).unwrap();
    assert_eq!(plain.cycles, traced.cycles);
    assert_eq!(plain.clusters.len(), traced.clusters.len());
    for (a, b) in plain.clusters.iter().zip(&traced.clusters) {
        assert_eq!(a.pmu, b.pmu);
    }
    // the per-layer stall breakdown the report table prints covers every
    // engine-level stall cycle (HostSync is system-level, not per-layer)
    let table_stalls: u64 = tr.layers.iter().map(|l| l.stall_breakdown.iter().sum::<u64>()).sum();
    let engine_stalls: u64 = traced
        .clusters
        .iter()
        .map(|c| c.pmu.total.stall_total() - c.pmu.total.stalls[StallReason::HostSync.index()])
        .sum();
    assert_eq!(table_stalls, engine_stalls);
}

#[test]
fn folded_profile_is_inferno_loadable() {
    // inferno's folded format: one "stack weight" line, frames ';'-joined
    let (_, tr) = sim::simulate_traced(&models::paper_mbv1(), &ArchConfig::j3dai()).unwrap();
    let text = tr.folded.render();
    assert!(!text.is_empty());
    let mut total_weight = 0u64;
    for line in text.lines() {
        let (stack, weight) = line.rsplit_once(' ').expect("stack<space>weight");
        let w: u64 = weight.parse().expect("integer weight");
        assert!(w > 0, "zero-weight line: {line}");
        assert_eq!(stack.split(';').count(), 3, "layer;cluster/engine;instr: {line}");
        total_weight += w;
    }
    assert!(total_weight > 0);
    assert!(text.contains("/COMPUTE;"), "no compute frames:\n{text}");
    assert!(text.contains("/XFER;"), "no transfer frames:\n{text}");
}

#[test]
fn cycle_domain_sampler_rings_and_serializes() {
    let cfg = ArchConfig::j3dai();
    let g = models::paper_mbv1();
    let (r, sampler) = sim::sample_timeseries(&g, &cfg, 2048, 32).unwrap();
    let windows = r.cycles.div_ceil(2048);
    assert_eq!(sampler.len() as u64 + sampler.dropped(), windows);
    assert!(sampler.len() <= 32);
    assert_eq!(sampler.series()[0], "cluster0_util");
    assert!(sampler.series().iter().any(|s| s == "power_mw_total"));
    for s in sampler.samples() {
        for (name, v) in sampler.series().iter().zip(&s.v) {
            if name.ends_with("_util") {
                assert!((0.0..=1.0).contains(v), "{name} = {v} out of range");
            }
        }
    }
    let doc = Json::parse(&sampler.to_json()).expect("valid JSON");
    let samples = doc.get("samples").and_then(Json::as_arr).unwrap();
    assert_eq!(samples.len(), sampler.len());
}

#[test]
fn frame_loop_publishes_cluster_series_exemplars_and_timeseries() {
    let g = models::tinycnn(Shape::new(24, 32, 3), 10);
    let tel = Telemetry::new(false);
    let ccfg =
        CoordinatorConfig { target_fps: 10_000.0, frames: 3, ..Default::default() };
    let stats = run_functional_loop(&g, &ccfg, &tel).unwrap();
    assert_eq!(stats.frames, 3);

    let text = tel.render_metrics();
    let stall0 = "j3dai_stall_cycles_total{cluster=\"0\",model=\"tinycnn\",reason=\"dma_wait\"}";
    assert!(text.contains(stall0), "missing {stall0} in:\n{text}");
    let energy0 = "j3dai_energy_mj_total{cluster=\"0\",model=\"tinycnn\"}";
    assert!(text.contains(energy0), "missing {energy0} in:\n{text}");
    // the labeled cluster series exist for every simulated cluster
    let cfg = ArchConfig::j3dai();
    let last = format!("j3dai_stall_cycles_total{{cluster=\"{}\"", cfg.clusters - 1);
    assert!(text.contains(&last), "missing {last} in:\n{text}");

    // exemplars only render behind the flag, and carry a frame trace id
    assert!(!text.contains("trace_id"), "{text}");
    let with = tel.registry.render_with_exemplars(true);
    assert!(with.contains("# {trace_id=\"frame"), "{with}");

    // one time-series snapshot per processed frame on the live endpoint
    let doc = Json::parse(&tel.export_timeseries_json()).expect("valid JSON");
    let series = doc.get("series").and_then(Json::as_arr).unwrap();
    assert!(series.iter().any(|s| s.as_str() == Some("queue_depth")), "{series:?}");
    assert!(series.iter().any(|s| s.as_str() == Some("energy_mj_total")), "{series:?}");
    let samples = doc.get("samples").and_then(Json::as_arr).unwrap();
    assert_eq!(samples.len(), 3);
}

#[test]
fn stall_and_roofline_reports_render_for_all_workloads() {
    let cfg = ArchConfig::j3dai();
    let em = j3dai::power::EnergyModel::fdsoi28();
    for g in paper_workloads() {
        let (r, tr) = sim::simulate_traced(&g, &cfg).unwrap();
        let stall = j3dai::report::render_stall_table(&g, &r);
        assert_eq!(stall.matches("[OK]").count(), cfg.clusters, "{stall}");
        assert!(!stall.contains("MISMATCH"), "{stall}");
        let cluster = j3dai::report::render_cluster_table(&r, &em);
        assert!(cluster.contains("E mJ"), "{cluster}");
        let svg = j3dai::report::roofline_svg(&tr, &cfg);
        assert!(svg.starts_with("<svg ") && svg.ends_with("</svg>\n"));
    }
}

#[test]
fn bench_compare_cli_gates_with_nonzero_exit() {
    // the acceptance bar: a latency regression past tolerance fails the
    // process (CI gate), while matching snapshots pass
    let snapshot = |latency: f64| {
        format!(
            "{{\"models\": [{{\"model\": \"mbv1_1_1\", \"latency_ms\": {latency}, \
             \"energy_mj\": 1.2, \"power_mw_30\": 47.6, \"power_mw_200\": null, \
             \"tops_per_w\": 0.77, \"mac_eff\": 0.768}}]}}"
        )
    };
    let dir = std::env::temp_dir().join(format!("j3dai_bc_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let base = dir.join("base.json");
    let good = dir.join("good.json");
    let bad = dir.join("bad.json");
    std::fs::write(&base, snapshot(5.0)).unwrap();
    std::fs::write(&good, snapshot(5.1)).unwrap();
    std::fs::write(&bad, snapshot(6.0)).unwrap();

    let run = |cand: &std::path::Path, extra: &[&str]| {
        std::process::Command::new(env!("CARGO_BIN_EXE_j3dai"))
            .arg("bench-compare")
            .arg(&base)
            .arg(cand)
            .args(extra)
            .output()
            .expect("spawn j3dai")
    };
    let ok = run(&good, &[]);
    assert!(ok.status.success(), "{}", String::from_utf8_lossy(&ok.stderr));
    assert!(String::from_utf8_lossy(&ok.stdout).contains("latency_ms"));

    let fail = run(&bad, &[]);
    assert!(!fail.status.success(), "20% latency regression must gate");
    assert!(String::from_utf8_lossy(&fail.stderr).contains("REGRESSION"));

    // a loose explicit tolerance lets the same diff through
    let loose = run(&bad, &["--latency-tol", "50"]);
    assert!(loose.status.success(), "{}", String::from_utf8_lossy(&loose.stderr));
    std::fs::remove_dir_all(&dir).ok();
}
