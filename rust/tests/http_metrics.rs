//! End-to-end exporter test (the `serve --metrics-addr` path): a
//! [`MetricsServer`] sharing one [`Telemetry`] domain with a running frame
//! loop must serve live Prometheus text and a valid Chrome trace over a
//! plain `TcpStream` *while frames flow*, and the scraped energy counters
//! must agree with the cycle simulator's model.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use j3dai::config::ArchConfig;
use j3dai::coordinator::{run_functional_loop, CoordinatorConfig};
use j3dai::graph::Shape;
use j3dai::power::EnergyModel;
use j3dai::telemetry::{json, metrics, MetricsServer, Telemetry};
use j3dai::{models, sim};

/// Minimal HTTP GET — deliberately raw `TcpStream`, no client library.
fn get(addr: SocketAddr, path: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n").unwrap();
    let mut text = String::new();
    s.read_to_string(&mut text).unwrap();
    let status = text.lines().next().unwrap_or("").to_string();
    let body = text.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

#[test]
fn metrics_endpoint_is_live_while_frames_flow() {
    let frames: u64 = 120;
    let g = models::tinycnn(Shape::new(24, 32, 3), 10);
    let cfg = ArchConfig::j3dai();
    let tel = Arc::new(Telemetry::new(true));
    let mut srv = MetricsServer::spawn("127.0.0.1:0", Arc::clone(&tel)).unwrap();
    let addr = srv.addr();

    let worker = {
        let tel = Arc::clone(&tel);
        let g = g.clone();
        let ccfg =
            CoordinatorConfig { target_fps: 500.0, frames, arch: cfg.clone(), ..Default::default() };
        std::thread::spawn(move || run_functional_loop(&g, &ccfg, &tel).unwrap())
    };

    // poll /metrics until the energy counter shows up with frames still in
    // flight — this is the "live while serving" acceptance criterion
    let energy_key = "j3dai_energy_mj_total{model=\"tinycnn\"}";
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut mid_frames = 0.0f64;
    loop {
        let (status, body) = get(addr, "/metrics");
        assert!(status.contains("200"), "{status}");
        let series = metrics::parse_text(&body).unwrap();
        if let Some(&mj) = series.get(energy_key) {
            if mj > 0.0 {
                mid_frames = series
                    .get("j3dai_frames_total{model=\"tinycnn\"}")
                    .copied()
                    .unwrap_or(0.0);
                break;
            }
        }
        assert!(Instant::now() < deadline, "energy series never appeared:\n{body}");
        std::thread::sleep(Duration::from_millis(10));
    }

    // /trace.json must be valid Chrome trace JSON mid-run too
    let (status, body) = get(addr, "/trace.json");
    assert!(status.contains("200"), "{status}");
    let doc = json::Json::parse(&body).unwrap();
    assert!(doc.get("traceEvents").and_then(json::Json::as_arr).is_some(), "no traceEvents");

    let stats = worker.join().unwrap();
    assert_eq!(stats.frames, frames);

    // final scrape: every frame accounted, energy matches the model
    let (_, body) = get(addr, "/metrics");
    let series = metrics::parse_text(&body).unwrap();
    let total_frames = series["j3dai_frames_total{model=\"tinycnn\"}"];
    assert_eq!(total_frames, frames as f64);
    assert!(mid_frames <= total_frames);

    let per_frame_mj =
        EnergyModel::fdsoi28().inference_mj(&sim::simulate(&g, &cfg).unwrap().activity);
    let total_mj = series[energy_key];
    let expect = per_frame_mj * frames as f64;
    assert!(
        (total_mj - expect).abs() <= expect * 1e-6,
        "scraped {total_mj} mJ, model says {expect} mJ"
    );
    // the component split sums back to the total
    let comp_sum: f64 = series
        .iter()
        .filter(|(k, _)| k.starts_with("j3dai_energy_component_mj_total{"))
        .map(|(_, v)| v)
        .sum();
    assert!(
        (comp_sum - total_mj).abs() <= expect * 1e-6,
        "components {comp_sum} vs total {total_mj}"
    );
    // gauges guard the fps<=0 path at the type level; here they are real
    let power_key = "j3dai_power_mw{model=\"tinycnn\"}";
    assert!(series[power_key].is_finite() && series[power_key] > 0.0);

    let (status, _) = get(addr, "/healthz");
    assert!(status.contains("200"));
    srv.shutdown();
}
