//! Property-based tests over the L3 coordinator-side invariants (the brief:
//! "proptest on coordinator invariants — routing, batching, state"), using
//! the in-repo runner (no proptest crate offline).

use j3dai::compiler::{self, mapper};
use j3dai::config::ArchConfig;
use j3dai::graph::{Graph, Op, Shape, INPUT};
use j3dai::isa::{Instr, Program};
use j3dai::ptest::{check, Gen};
use j3dai::quant::{QAdd, Requant};
use j3dai::sim::{engine, pe};

/// Random small CNN graph generator.
fn random_graph(g: &mut Gen) -> Graph {
    let h = g.usize_in(8, 40) & !1; // even
    let w = g.usize_in(8, 48) & !1;
    let mut gr = Graph::new("prop", Shape::new(h.max(8), w.max(8), 3));
    let mut last = INPUT;
    let n_layers = g.usize_in(1, 6);
    for i in 0..n_layers {
        let cout = 8 * g.usize_in(1, 6);
        let stride = if g.bool() { 1 } else { 2 };
        let cur_shape = if last == INPUT { gr.input } else { gr.layers[last].out_shape };
        let op = match g.usize_in(0, 2) {
            0 => Op::Conv { kh: 3, kw: 3, cout, stride, relu: g.bool() },
            1 => Op::Conv { kh: 1, kw: 1, cout, stride: 1, relu: true },
            _ => Op::DwConv { stride: if cur_shape.h >= 2 && cur_shape.w >= 2 { stride } else { 1 } },
        };
        last = gr.push(format!("prop/l{i}"), op, vec![last]);
    }
    gr
}

#[test]
fn prop_mac_conservation_any_graph_any_arch() {
    // The compiler may never lose or duplicate MACs, whatever the graph
    // shape or array geometry.
    check("mac-conservation", 40, |g| {
        let gr = random_graph(g);
        let cfg = ArchConfig::scaled(g.usize_in(1, 8), *g.pick(&[4, 8, 16]), *g.pick(&[4, 8]));
        let c = compiler::compile(&gr, &cfg).unwrap();
        assert_eq!(c.total_macs(), gr.total_macs());
    });
}

#[test]
fn prop_split_rows_partitions_exactly() {
    check("split-rows", 100, |g| {
        let m = g.usize_in(0, 10_000);
        let clusters = g.usize_in(1, 64);
        let parts = mapper::split_rows(m, clusters);
        assert_eq!(parts.len(), clusters);
        assert_eq!(parts.iter().sum::<usize>(), m);
        let (mn, mx) = (parts.iter().min().unwrap(), parts.iter().max().unwrap());
        assert!(mx - mn <= 1, "unbalanced split: {parts:?}");
    });
}

#[test]
fn prop_requant_monotone_in_acc() {
    // requant is monotone: a larger accumulator never yields a smaller code.
    check("requant-monotone", 60, |g| {
        let rq = Requant {
            mult: g.i32_in(1, 1 << 22),
            shift: g.usize_in(8, 30) as u32,
            zp_out: g.i32_in(0, 255),
            act_min: 0,
            act_max: 255,
        };
        let a = g.i32_in(-1_000_000, 1_000_000);
        let b = g.i32_in(-1_000_000, 1_000_000);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        assert!(rq.apply(lo) <= rq.apply(hi));
    });
}

#[test]
fn prop_qadd_bounds_and_symmetry() {
    check("qadd", 60, |g| {
        let p = QAdd::default_params();
        let a = g.u8();
        let b = g.u8();
        let y = p.apply(a, b);
        let (lo, hi) = (a.min(b), a.max(b));
        // averaging two codes stays within their span (plus rounding slack)
        assert!(y as i32 >= lo as i32 - 1 && y as i32 <= hi as i32 + 1, "a={a} b={b} y={y}");
        assert_eq!(p.apply(a, b), p.apply(b, a));
    });
}

#[test]
fn prop_isa_roundtrip() {
    check("isa-roundtrip", 80, |g| {
        let instr = match g.usize_in(0, 6) {
            0 => Instr::DmpaLoad {
                src: *g.pick(&[j3dai::isa::Space::L2Bottom, j3dai::isa::Space::L2Middle]),
                src_addr: g.u64() as u32,
                dst_addr: g.u64() as u32,
                bytes: g.u64() as u32,
            },
            1 => Instr::ConvTile {
                m: g.u64() as u32,
                k: g.u64() as u32,
                n: g.u64() as u32,
                first: g.bool(),
                last: g.bool(),
            },
            2 => Instr::DwTile { h: g.u64() as u32, w: g.u64() as u32, c: g.u64() as u32, stride: g.usize_in(1, 2) as u8 },
            3 => Instr::AiuLoop { reg: g.usize_in(0, 7) as u8, count: g.u64() as u32, stride: g.u64() as u32 },
            4 => Instr::AddTile { n: g.u64() as u32 },
            5 => Instr::Sync,
            _ => Instr::Halt,
        };
        let decoded = Instr::decode(&instr.encode()).unwrap();
        assert_eq!(instr, decoded);
    });
}

#[test]
fn prop_engine_cycles_monotone_in_work() {
    // Adding an instruction can never reduce a cluster's cycle count.
    check("engine-monotone", 40, |g| {
        let cfg = ArchConfig::j3dai();
        let mut instrs = Vec::new();
        for _ in 0..g.usize_in(1, 20) {
            instrs.push(match g.usize_in(0, 3) {
                0 => Instr::DmpaLoad {
                    src: j3dai::isa::Space::L2Bottom,
                    src_addr: 0,
                    dst_addr: 0,
                    bytes: g.u64() as u32 % 100_000,
                },
                1 => Instr::ConvTile {
                    m: g.usize_in(1, 128) as u32,
                    k: g.usize_in(1, 512) as u32,
                    n: g.usize_in(1, 128) as u32,
                    first: true,
                    last: true,
                },
                2 => Instr::Sync,
                _ => Instr::AddTile { n: g.usize_in(1, 4096) as u32 },
            });
        }
        let base = engine::run_cluster(&cfg, &Program { instrs: instrs.clone() }, 1).cycles;
        instrs.insert(
            g.usize_in(0, instrs.len()),
            Instr::ConvTile { m: 8, k: 8, n: 8, first: true, last: true },
        );
        let more = engine::run_cluster(&cfg, &Program { instrs }, 1).cycles;
        assert!(more >= base, "more={more} base={base}");
    });
}

#[test]
fn prop_nlu_monotone_any_zero_point() {
    check("nlu-monotone", 30, |g| {
        let zp = g.i32_in(0, 255);
        let mut prev = 0u8;
        for x in 0..=255u16 {
            let y = pe::nlu_sigmoid(x as u8, zp);
            assert!(y >= prev, "zp={zp} x={x}");
            prev = y;
        }
    });
}

#[test]
fn prop_placement_never_overlaps_live_tensors() {
    check("placement-liveness", 25, |g| {
        let gr = random_graph(g);
        let cfg = ArchConfig::j3dai();
        let p = mapper::place_memory(&gr, &cfg).unwrap();
        // recompute liveness and assert no overlap between any tensor and
        // its consumers' other live inputs
        let mut last_use = vec![0usize; gr.layers.len()];
        for (i, l) in gr.layers.iter().enumerate() {
            for &j in &l.inputs {
                if j != INPUT {
                    last_use[j] = i;
                }
            }
        }
        for i in 0..gr.layers.len() {
            for j in 0..i {
                if last_use[j] >= i {
                    let a = &p.activations[i];
                    let b = &p.activations[j];
                    let overlap = a.addr < b.addr + b.bytes && b.addr < a.addr + a.bytes;
                    assert!(!overlap, "layer {i} clobbers live {j}");
                }
            }
        }
    });
}
