//! End-to-end pipeline integration: sensor -> coordinator -> PJRT runtime
//! + cycle simulator, exercising the full L3 stack the way `j3dai serve`
//! does, plus compiler/simulator integration across configurations.

use j3dai::config::ArchConfig;
use j3dai::coordinator::{Coordinator, CoordinatorConfig};
use j3dai::graph::Shape;
use j3dai::models;
use j3dai::power::EnergyModel;
use j3dai::runtime;
use j3dai::sensor::{subsample, PixelArray};
use j3dai::sim;

fn artifacts_ready() -> bool {
    runtime::default_artifact_dir().join("manifest.txt").exists()
}

#[test]
fn coordinator_frame_loop_end_to_end() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let coord = Coordinator::new(
        &runtime::default_artifact_dir(),
        CoordinatorConfig { target_fps: 120.0, frames: 12, ..Default::default() },
    )
    .unwrap();
    let stats = coord.run_model("tinycnn_24x32").unwrap();
    assert_eq!(stats.frames, 12);
    assert!(stats.achieved_fps > 1.0, "fps={}", stats.achieved_fps);
    assert!(stats.mean_service_us > 0.0);
    assert!(stats.modeled_latency_ms > 0.0);
    assert!(stats.modeled_power_mw_at_fps > 0.0);
    // frames vary -> classifications may vary, but all must be valid classes
    assert!(stats.records.iter().all(|r| r.top_class < 10));
}

#[test]
fn coordinator_runs_every_artifact_model() {
    if !artifacts_ready() {
        return;
    }
    let coord = Coordinator::new(
        &runtime::default_artifact_dir(),
        CoordinatorConfig { target_fps: 500.0, frames: 3, ..Default::default() },
    )
    .unwrap();
    let mut names = coord.model_names();
    names.sort();
    assert_eq!(names.len(), 4);
    for name in names {
        let stats = coord.run_model(&name).unwrap();
        assert_eq!(stats.frames, 3, "{name}");
    }
}

#[test]
fn sensor_feeds_dnn_input_resolutions() {
    // full chain: 12 Mpix-equivalent capture -> subsample -> DNN input
    let pixels = PixelArray::new(99);
    let hi = pixels.capture(0, Shape::new(384, 512, 3));
    let lo = subsample(&hi, 2);
    assert_eq!(lo.shape, Shape::new(192, 256, 3)); // classifier input
}

#[test]
fn table1_shape_holds_across_the_stack() {
    // The headline reproduction: per-model latency ordering, efficiency
    // ordering, and the paper's power ordering all hold simultaneously.
    let cfg = ArchConfig::j3dai();
    let em = EnergyModel::fdsoi28();
    let v1 = sim::simulate(&models::paper_mbv1(), &cfg).unwrap();
    let v2 = sim::simulate(&models::paper_mbv2(), &cfg).unwrap();
    let sg = sim::simulate(&models::paper_seg(), &cfg).unwrap();

    // latency: v2 < v1 < seg (paper: 4.04 < 4.96 < 7.43 ms)
    assert!(v2.latency_ms < v1.latency_ms && v1.latency_ms < sg.latency_ms);
    // latency within 5% of paper
    assert!((v1.latency_ms - 4.96).abs() / 4.96 < 0.05, "{}", v1.latency_ms);
    assert!((v2.latency_ms - 4.04).abs() / 4.04 < 0.05, "{}", v2.latency_ms);
    assert!((sg.latency_ms - 7.43).abs() / 7.43 < 0.05, "{}", sg.latency_ms);
    // efficiency: v1 ~ seg >> v2 (paper: 76.8 / 76.5 / 46.6)
    assert!((v1.mac_efficiency - 0.768).abs() < 0.05);
    assert!((sg.mac_efficiency - 0.765).abs() < 0.05);
    assert!((v2.mac_efficiency - 0.466).abs() < 0.05);
    // power @30FPS within 10% of paper (47.6 / 30.5 / 63.8 mW)
    let p = |r: &sim::SimResult| r.power_mw(&em, 30.0).unwrap();
    assert!((p(&v1) - 47.6).abs() / 47.6 < 0.10, "{}", p(&v1));
    assert!((p(&v2) - 30.5).abs() / 30.5 < 0.10, "{}", p(&v2));
    assert!((p(&sg) - 63.8).abs() / 63.8 < 0.10, "{}", p(&sg));
    // power @200FPS: v1/v2 sustain it, seg cannot (paper prints "-")
    assert!(v1.power_mw(&em, 200.0).is_some());
    assert!(v2.power_mw(&em, 200.0).is_some());
    assert!(sg.power_mw(&em, 200.0).is_none());
}

#[test]
fn table2_shape_holds() {
    // J3DAI: smallest chip, fewest MACs, highest power, best GOPS/W/mm^2.
    let cfg = ArchConfig::j3dai();
    let em = EnergyModel::fdsoi28();
    let mbv2 = sim::simulate(&models::paper_mbv2(), &cfg).unwrap();
    let mut cols = j3dai::report::sony_columns();
    cols.push(j3dai::report::j3dai_column(&cfg, &mbv2, &em));
    let j = cols.last().unwrap();
    for sony in &cols[..2] {
        assert!(j.chip_mm2 < sony.chip_mm2);
        assert!(j.dnn_mem_mm2 < sony.dnn_mem_mm2);
        assert!(j.macs < sony.macs);
        assert!(j.power_mw_200fps.unwrap() > sony.power_mw_200fps.unwrap());
        assert!(j.gops_w_mm2().unwrap() > sony.gops_w_mm2().unwrap());
    }
    // MAC efficiency between the two SONY points (paper: 13.4 < 46.6 < 59.9)
    assert!(j.mac_eff_pct > cols[0].mac_eff_pct && j.mac_eff_pct < cols[1].mac_eff_pct);
}

#[test]
fn compile_then_simulate_is_deterministic() {
    let g = models::mobilenet_v1(1, 4, Shape::new(48, 64, 3), 100);
    let cfg = ArchConfig::j3dai();
    let a = sim::simulate(&g, &cfg).unwrap();
    let b = sim::simulate(&g, &cfg).unwrap();
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.activity, b.activity);
}

#[test]
fn voltage_scaling_reduces_power_not_latency() {
    let cfg = ArchConfig::j3dai();
    let r = sim::simulate(&models::paper_mbv2(), &cfg).unwrap();
    let em = EnergyModel::fdsoi28();
    let low = em.at_voltage(0.6, 0.85);
    assert!(low.power_mw(&r.activity, 30.0) < em.power_mw(&r.activity, 30.0));
    // latency is a cycle count: unchanged by voltage in this model
    assert_eq!(r.latency_ms, sim::simulate(&models::paper_mbv2(), &cfg).unwrap().latency_ms);
}

#[test]
fn multi_network_interleaved_serving() {
    // §IV-A: the 5 MB L2 "enables the execution of several networks";
    // serve classification and segmentation alternately from one runtime
    // (both artifact sets resident), as a sensor alternating between a
    // cheap detector and an expensive segmentation pass would.
    if !artifacts_ready() {
        return;
    }
    let mut rt = j3dai::runtime::Runtime::new().unwrap();
    rt.load_all(&runtime::default_artifact_dir()).unwrap();
    let cls = rt.entry("mbv1_w25_48x64").unwrap().clone();
    let seg = rt.entry("fpnseg_w25_48x64").unwrap().clone();
    let pixels = PixelArray::new(5);
    for i in 0..6u64 {
        let frame = pixels.capture(i, cls.input_shape);
        let (name, dims) = if i % 2 == 0 {
            ("mbv1_w25_48x64", &cls.output_dims)
        } else {
            ("fpnseg_w25_48x64", &seg.output_dims)
        };
        let out = rt.infer(name, &frame).unwrap();
        assert_eq!(out.len(), dims.iter().product::<usize>(), "{name}");
    }
    // and the L2 budget claim itself: both param sets fit simultaneously
    let cfg = ArchConfig::j3dai();
    let p1 = models::artifact_graph("mbv1_w25_48x64").unwrap().total_param_bytes();
    let p2 = models::artifact_graph("fpnseg_w25_48x64").unwrap().total_param_bytes();
    assert!(p1 + p2 < cfg.l2_bytes() as u64);
}

#[test]
fn sim_energy_consistency_between_power_and_coordinator() {
    // the coordinator's modeled power must equal EnergyModel applied to
    // the presimulated activity (no duplicated accounting)
    if !artifacts_ready() {
        return;
    }
    let coord = Coordinator::new(
        &runtime::default_artifact_dir(),
        CoordinatorConfig { target_fps: 1000.0, frames: 2, ..Default::default() },
    )
    .unwrap();
    let simr = coord.presimulate("tinycnn_24x32").unwrap();
    let em = EnergyModel::fdsoi28();
    let stats = coord.run_model("tinycnn_24x32").unwrap();
    let expect = em.power_mw(&simr.activity, 1000.0f64.min(simr.max_fps));
    assert!((stats.modeled_power_mw_at_fps - expect).abs() < 1e-9);
}
