//! Parallelism correctness: the cluster-parallel simulation must be
//! bit-identical to the serial path on every Table I workload, and the
//! multi-worker frame pipeline must reassemble records in frame order
//! with per-worker telemetry intact.

use j3dai::config::ArchConfig;
use j3dai::coordinator::{run_functional_loop, CoordinatorConfig};
use j3dai::graph::Shape;
use j3dai::telemetry::{json::Json, metrics, Telemetry, FRAME_PID};
use j3dai::{compiler, models, sim};

/// Determinism gate (ISSUE 10 acceptance): `threads=1` vs `threads=4`
/// produce identical cycles, per-cluster PMU banks, Activity and folded
/// profiles on all three Table I workloads.
#[test]
fn parallel_simulation_is_bit_identical_on_table1_workloads() {
    let cfg = ArchConfig::j3dai();
    for g in [models::paper_mbv1(), models::paper_mbv2(), models::paper_seg()] {
        let compiled = compiler::compile(&g, &cfg).unwrap();

        let serial = sim::simulate_compiled_threads(&g, &cfg, &compiled, 1);
        let par = sim::simulate_compiled_threads(&g, &cfg, &compiled, 4);
        assert_eq!(serial.cycles, par.cycles, "{}", g.name);
        assert_eq!(serial.host_cycles, par.host_cycles, "{}", g.name);
        assert_eq!(serial.activity, par.activity, "{}", g.name);
        assert_eq!(serial.clusters.len(), par.clusters.len(), "{}", g.name);
        for (ci, (a, b)) in serial.clusters.iter().zip(&par.clusters).enumerate() {
            assert_eq!(a.cycles, b.cycles, "{} cluster {ci}", g.name);
            assert_eq!(a.activity, b.activity, "{} cluster {ci}", g.name);
            assert_eq!(a.compute_busy, b.compute_busy, "{} cluster {ci}", g.name);
            assert_eq!(a.xfer_busy, b.xfer_busy, "{} cluster {ci}", g.name);
            assert_eq!(a.pmu, b.pmu, "{} cluster {ci}", g.name);
        }

        // traced path: span stream and folded profile are byte-identical
        let (rs, ts) = sim::simulate_compiled_traced_threads(&g, &cfg, &compiled, 1);
        let (rp, tp) = sim::simulate_compiled_traced_threads(&g, &cfg, &compiled, 4);
        assert_eq!(rs.cycles, rp.cycles, "{}", g.name);
        assert_eq!(rs.activity, rp.activity, "{}", g.name);
        assert_eq!(ts.trace.events, tp.trace.events, "{}", g.name);
        assert_eq!(ts.folded, tp.folded, "{}", g.name);
        assert_eq!(ts.folded.render(), tp.folded.render(), "{}", g.name);

        // plain entry point matches the threaded one at any count
        let plain = sim::simulate_compiled(&g, &cfg, &compiled);
        assert_eq!(plain.cycles, par.cycles, "{}", g.name);
    }
}

/// More workers than clusters must neither panic nor change results.
#[test]
fn thread_oversubscription_is_safe() {
    let g = models::tinycnn(Shape::new(24, 32, 3), 10);
    let cfg = ArchConfig::j3dai();
    let compiled = compiler::compile(&g, &cfg).unwrap();
    let serial = sim::simulate_compiled_threads(&g, &cfg, &compiled, 1);
    let par = sim::simulate_compiled_threads(&g, &cfg, &compiled, 64);
    assert_eq!(serial.cycles, par.cycles);
    assert_eq!(serial.activity, par.activity);
}

/// With M workers the frame loop must emit records in frame order, name
/// every worker thread `infer-0..M-1` in the trace, and account each
/// processed frame to exactly one worker counter.
#[test]
fn multi_worker_frame_loop_reassembles_in_order() {
    let workers = 4usize;
    let frames = 16u64;
    let g = models::tinycnn(Shape::new(24, 32, 3), 10);

    let baseline = {
        let tel = Telemetry::disabled();
        let ccfg = CoordinatorConfig {
            target_fps: 10_000.0,
            frames,
            workers: 1,
            ..Default::default()
        };
        run_functional_loop(&g, &ccfg, &tel).unwrap()
    };

    let tel = Telemetry::new(true);
    let ccfg = CoordinatorConfig {
        target_fps: 10_000.0,
        frames,
        workers,
        ..Default::default()
    };
    let stats = run_functional_loop(&g, &ccfg, &tel).unwrap();

    // in-order reassembly: records carry consecutive frame indices and the
    // per-frame classifications match the single-worker run exactly
    assert_eq!(stats.frames, frames);
    assert_eq!(stats.records.len(), frames as usize);
    for (i, r) in stats.records.iter().enumerate() {
        assert_eq!(r.frame_idx, i as u64, "records out of order");
        assert_eq!(r.top_class, baseline.records[i].top_class, "frame {i}");
    }

    // every worker thread is named in the trace metadata and the exported
    // Chrome JSON, and every infer span ran on a worker tid
    let tr = tel.take_trace();
    assert_eq!(tr.thread_label(FRAME_PID, 0), Some("capture"));
    for wi in 0..workers {
        assert_eq!(
            tr.thread_label(FRAME_PID, 1 + wi as u32),
            Some(format!("infer-{wi}").as_str()),
            "worker {wi} unnamed"
        );
    }
    let json = tr.to_chrome_json();
    for wi in 0..workers {
        assert!(json.contains(&format!("infer-{wi}")), "infer-{wi} missing from trace JSON");
    }
    let infer_spans: Vec<_> = tr.events.iter().filter(|e| e.name == "infer").collect();
    assert_eq!(infer_spans.len(), frames as usize);
    for e in &infer_spans {
        assert!(
            (1..=workers as u32).contains(&e.tid),
            "infer span on unexpected tid {}",
            e.tid
        );
    }

    // per-worker counters account every frame exactly once
    let series = metrics::parse_text(&tel.render_metrics()).unwrap();
    let worker_total: f64 = series
        .iter()
        .filter(|(k, _)| k.starts_with("j3dai_worker_frames_total{"))
        .map(|(_, v)| v)
        .sum();
    assert_eq!(worker_total, frames as f64);
}

/// The collector feeds the ring sampler from one thread, so M workers must
/// not tear or reorder the time series: one snapshot per frame, timestamps
/// non-decreasing, nothing dropped at this capacity.
#[test]
fn frame_loop_sampler_survives_many_workers() {
    let frames = 12u64;
    let g = models::tinycnn(Shape::new(24, 32, 3), 10);
    let tel = Telemetry::new(false);
    let ccfg = CoordinatorConfig {
        target_fps: 10_000.0,
        frames,
        workers: 4,
        ..Default::default()
    };
    run_functional_loop(&g, &ccfg, &tel).unwrap();

    let doc = Json::parse(&tel.export_timeseries_json()).unwrap();
    let samples = doc.get("samples").and_then(Json::as_arr).unwrap();
    assert_eq!(samples.len(), frames as usize);
    assert_eq!(doc.get("dropped").and_then(Json::as_f64), Some(0.0));
    let mut prev = f64::MIN;
    for s in samples {
        let t = s.get("t").and_then(Json::as_f64).unwrap();
        assert!(t >= prev, "sampler timestamps ran backwards");
        prev = t;
        assert_eq!(s.get("v").and_then(Json::as_arr).unwrap().len(), 4);
    }
}
