//! Telemetry integration: traced simulation produces a valid
//! Perfetto-loadable trace with per-layer coverage, the frame loop
//! publishes its metrics, and disabled tracing stays free.

use j3dai::config::ArchConfig;
use j3dai::coordinator::{run_functional_loop, CoordinatorConfig};
use j3dai::graph::Shape;
use j3dai::models;
use j3dai::sim;
use j3dai::telemetry::{json::Json, Telemetry, TraceBuilder, SIM_PID};

#[test]
fn trace_covers_every_layer_with_both_engines() {
    let g = models::artifact_graph("mbv1_w25_48x64").unwrap();
    let cfg = ArchConfig::j3dai();
    let (_, tr) = sim::simulate_traced(&g, &cfg).unwrap();

    // >= 1 span per graph layer (the acceptance bar for `j3dai trace`)
    assert_eq!(tr.layers.len(), g.layers.len());
    let layers_tid = cfg.clusters as u32 * 2;
    let layer_spans =
        tr.trace.events.iter().filter(|e| e.pid == SIM_PID && e.tid == layers_tid).count();
    assert_eq!(layer_spans, g.layers.len());

    // separate COMPUTE and XFER tracks per cluster, each carrying spans
    for ci in 0..cfg.clusters as u32 {
        assert_eq!(
            tr.trace.thread_label(SIM_PID, ci * 2),
            Some(format!("cluster{ci}/COMPUTE").as_str())
        );
        assert_eq!(
            tr.trace.thread_label(SIM_PID, ci * 2 + 1),
            Some(format!("cluster{ci}/XFER").as_str())
        );
        assert!(tr.trace.events.iter().any(|e| e.tid == ci * 2), "cluster {ci} compute empty");
        assert!(tr.trace.events.iter().any(|e| e.tid == ci * 2 + 1), "cluster {ci} xfer empty");
    }
}

#[test]
fn chrome_export_parses_and_roundtrips() {
    let g = models::tinycnn(Shape::new(24, 32, 3), 10);
    let (_, tr) = sim::simulate_traced(&g, &ArchConfig::j3dai()).unwrap();
    let text = tr.trace.to_chrome_json();

    // valid JSON with the Chrome trace-event envelope
    let doc = Json::parse(&text).unwrap();
    assert!(doc.get("traceEvents").and_then(Json::as_arr).is_some());

    // and the exporter's own parser reads back the identical span set
    let back = TraceBuilder::from_chrome_json(&text).unwrap();
    assert_eq!(back.events, tr.trace.events);
}

#[test]
fn every_sim_span_carries_energy() {
    // acceptance bar for the energy attribution: every instruction- and
    // layer-level span exported to Perfetto has a finite, non-negative
    // args.energy_pj (compute spans strictly positive — MAC + ctrl energy)
    let g = models::tinycnn(Shape::new(24, 32, 3), 10);
    let cfg = ArchConfig::j3dai();
    let (_, tr) = sim::simulate_traced(&g, &cfg).unwrap();
    let host_tid = cfg.clusters as u32 * 2 + 1;
    let mut checked = 0usize;
    for e in tr.trace.events.iter().filter(|e| e.pid == SIM_PID && e.tid != host_tid) {
        let pj = e
            .args
            .iter()
            .find(|(k, _)| k == "energy_pj")
            .and_then(|(_, v)| v.as_f64())
            .unwrap_or_else(|| panic!("span {} has no energy_pj arg", e.name));
        assert!(pj.is_finite() && pj >= 0.0, "span {}: energy_pj={pj}", e.name);
        if e.tid % 2 == 0 && e.tid < host_tid - 1 {
            assert!(pj > 0.0, "compute span {} reports zero energy", e.name);
        }
        checked += 1;
    }
    assert!(checked > 0);
}

#[test]
fn disabled_tracing_costs_under_five_percent() {
    let g = models::paper_mbv1();
    let cfg = ArchConfig::j3dai();
    // warm up caches/allocator
    let _ = sim::simulate(&g, &cfg).unwrap();
    let _ = sim::simulate_traced(&g, &cfg).unwrap();

    let min_of = |f: &mut dyn FnMut()| -> f64 {
        (0..8)
            .map(|_| {
                let t = std::time::Instant::now();
                f();
                t.elapsed().as_secs_f64()
            })
            .fold(f64::MAX, f64::min)
    };
    let untraced = min_of(&mut || drop(sim::simulate(&g, &cfg)));
    let traced = min_of(&mut || drop(sim::simulate_traced(&g, &cfg)));
    // the NullSink path monomorphizes the span recording away: running with
    // tracing disabled must not cost more than the traced run plus 5%
    assert!(
        untraced <= traced * 1.05,
        "untraced {untraced:.6}s vs traced {traced:.6}s — disabled tracing is not free"
    );
}

#[test]
fn functional_frame_loop_publishes_metrics() {
    let g = models::tinycnn(Shape::new(24, 32, 3), 10);
    let tel = Telemetry::new(true);
    let ccfg = CoordinatorConfig {
        target_fps: 10_000.0, // effectively unpaced: no sleeps in CI
        frames: 4,
        arch: ArchConfig::j3dai(),
        ..Default::default()
    };
    let stats = run_functional_loop(&g, &ccfg, &tel).unwrap();
    assert_eq!(stats.frames, 4);
    assert_eq!(stats.records.len(), 4);
    assert!(stats.mean_service_us > 0.0);
    assert!(stats.p99_service_us >= stats.mean_service_us);

    let text = tel.render_metrics();
    for series in [
        "j3dai_frames_total{model=\"tinycnn\"} 4",
        "# TYPE j3dai_inference_service_us histogram",
        "j3dai_inference_service_us_count{model=\"tinycnn\"} 4",
        "# TYPE j3dai_queue_depth gauge",
        "# TYPE j3dai_achieved_fps gauge",
        "# TYPE j3dai_capture_us histogram",
    ] {
        assert!(text.contains(series), "missing {series} in:\n{text}");
    }

    // per-frame capture + infer spans on the frame-loop pid
    let tr = tel.take_trace();
    assert_eq!(tr.events.iter().filter(|e| e.name == "infer").count(), 4);
    assert_eq!(tr.events.iter().filter(|e| e.name == "capture").count(), 4);
}

#[test]
fn zero_frame_run_returns_empty_stats() {
    // regression: `run_model`/the frame loop used to underflow on
    // `service.len() - 1` and divide by zero when no frames arrived
    let g = models::tinycnn(Shape::new(24, 32, 3), 10);
    let tel = Telemetry::disabled();
    let ccfg =
        CoordinatorConfig { target_fps: 10_000.0, frames: 0, ..Default::default() };
    let stats = run_functional_loop(&g, &ccfg, &tel).unwrap();
    assert_eq!(stats.frames, 0);
    assert!(stats.records.is_empty());
    assert_eq!(stats.mean_service_us, 0.0);
    assert_eq!(stats.p99_service_us, 0.0);
    assert_eq!(stats.achieved_fps, 0.0);
}
