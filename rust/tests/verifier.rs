//! Verifier + ISA-hardening integration suite: exhaustive encode/decode
//! round-trips over every `Instr` variant, the decode/disassemble error
//! paths, clean verification of all three Table I workloads, and one
//! mutation test per verifier pass on each workload (the corrupted
//! program must produce an error-severity diagnostic, which is exactly
//! what makes `j3dai lint` exit non-zero).

use j3dai::compiler;
use j3dai::config::ArchConfig;
use j3dai::graph::Shape;
use j3dai::isa::{Instr, Program, Space, NUM_AIU_LOOP_REGS};
use j3dai::models;
use j3dai::ptest::{check, Gen};
use j3dai::telemetry::json::Json;
use j3dai::verify::{sarif, verify_programs, VerifyPolicy, VerifyReport};

fn space(g: &mut Gen) -> Space {
    *g.pick(&[Space::L2Bottom, Space::L2Middle, Space::Local])
}

/// One random instance of each of the 14 `Instr` variants, by index.
fn any_instr(g: &mut Gen, variant: usize) -> Instr {
    match variant {
        0 => Instr::DmpaLoad {
            src: space(g),
            src_addr: g.u64() as u32,
            dst_addr: g.u64() as u32,
            bytes: g.u64() as u32,
        },
        1 => Instr::DmpaStore {
            dst: space(g),
            dst_addr: g.u64() as u32,
            src_addr: g.u64() as u32,
            bytes: g.u64() as u32,
        },
        2 => Instr::DmaLoad {
            src: space(g),
            src_addr: g.u64() as u32,
            dst_addr: g.u64() as u32,
            bytes: g.u64() as u32,
        },
        3 => Instr::DmaStore {
            dst: space(g),
            dst_addr: g.u64() as u32,
            src_addr: g.u64() as u32,
            bytes: g.u64() as u32,
        },
        4 => Instr::AiuLoop {
            reg: g.usize_in(0, NUM_AIU_LOOP_REGS as usize - 1) as u8,
            count: g.u64() as u32,
            stride: g.u64() as u32,
        },
        5 => Instr::RouteCfg { pattern: g.u8() },
        6 => Instr::ConvTile {
            m: g.u64() as u32,
            k: g.u64() as u32,
            n: g.u64() as u32,
            first: g.bool(),
            last: g.bool(),
        },
        7 => Instr::DwTile { h: g.u64() as u32, w: g.u64() as u32, c: g.u64() as u32, stride: g.u8() },
        8 => Instr::AddTile { n: g.u64() as u32 },
        9 => Instr::ActTile { n: g.u64() as u32, nlu: g.bool() },
        10 => Instr::PoolTile { h: g.u64() as u32, w: g.u64() as u32, c: g.u64() as u32 },
        11 => Instr::LayerMark { id: g.u64() as u32 },
        12 => Instr::Sync,
        _ => Instr::Halt,
    }
}

#[test]
fn fixed_instance_of_every_variant_roundtrips() {
    // deterministic floor under the property test: one hand-picked
    // instance per variant, covering all three spaces across transfers
    let all = vec![
        Instr::DmpaLoad { src: Space::L2Bottom, src_addr: 1, dst_addr: 2, bytes: 3 },
        Instr::DmpaStore { dst: Space::L2Middle, dst_addr: 4, src_addr: 5, bytes: 6 },
        Instr::DmaLoad { src: Space::Local, src_addr: 7, dst_addr: 8, bytes: 9 },
        Instr::DmaStore { dst: Space::L2Bottom, dst_addr: 10, src_addr: 11, bytes: 12 },
        Instr::AiuLoop { reg: NUM_AIU_LOOP_REGS - 1, count: 13, stride: 14 },
        Instr::RouteCfg { pattern: 255 },
        Instr::ConvTile { m: 15, k: 16, n: 17, first: true, last: false },
        Instr::DwTile { h: 18, w: 19, c: 20, stride: 2 },
        Instr::AddTile { n: 21 },
        Instr::ActTile { n: 22, nlu: true },
        Instr::PoolTile { h: 23, w: 24, c: 25 },
        Instr::LayerMark { id: 26 },
        Instr::Sync,
        Instr::Halt,
    ];
    for instr in all {
        let decoded = Instr::decode(&instr.encode()).unwrap();
        assert_eq!(instr, decoded);
    }
}

#[test]
fn prop_every_instr_variant_roundtrips() {
    // random field values over a uniformly drawn variant index
    check("instr-roundtrip-exhaustive", 140, |g| {
        let variant = g.usize_in(0, 13);
        let instr = any_instr(g, variant);
        let decoded = Instr::decode(&instr.encode()).unwrap();
        assert_eq!(instr, decoded, "variant {variant}");
    });
}

#[test]
fn prop_programs_of_any_variants_roundtrip_binary() {
    check("program-roundtrip", 40, |g| {
        let mut instrs: Vec<Instr> = (0..g.usize_in(0, 30))
            .map(|_| {
                // everything except Halt mid-program (trailing garbage rule)
                let v = g.usize_in(0, 12);
                any_instr(g, v)
            })
            .collect();
        instrs.push(Instr::Halt);
        let p = Program { instrs };
        let q = Program::disassemble(&p.assemble()).unwrap();
        assert_eq!(p.instrs, q.instrs);
    });
}

#[test]
fn decode_rejects_bad_discriminants_naming_offsets() {
    // unknown opcode -> byte offset 0
    let mut w = [0u8; 16];
    w[0] = 0x7f;
    let e = Instr::decode(&w).unwrap_err().to_string();
    assert!(e.contains("unknown opcode") && e.contains("byte offset 0"), "{e}");

    // bad space code -> byte offset 1
    let mut w = [0u8; 16];
    w[0] = 0x01; // DmpaLoad
    w[1] = 9;
    let e = Instr::decode(&w).unwrap_err().to_string();
    assert!(e.contains("space code 9") && e.contains("byte offset 1"), "{e}");

    // AIU loop register out of range -> byte offset 1
    let mut w = [0u8; 16];
    w[0] = 0x05; // AiuLoop
    w[1] = NUM_AIU_LOOP_REGS;
    let e = Instr::decode(&w).unwrap_err().to_string();
    assert!(e.contains("loop register") && e.contains("byte offset 1"), "{e}");

    // ConvTile flag bits beyond first|last -> byte offset 1
    let mut w = [0u8; 16];
    w[0] = 0x10; // ConvTile
    w[1] = 0b100;
    let e = Instr::decode(&w).unwrap_err().to_string();
    assert!(e.contains("flag bits") && e.contains("byte offset 1"), "{e}");

    // ActTile nlu byte must be 0/1
    let mut w = [0u8; 16];
    w[0] = 0x13; // ActTile
    w[1] = 2;
    let e = Instr::decode(&w).unwrap_err().to_string();
    assert!(e.contains("nlu byte") && e.contains("byte offset 1"), "{e}");
}

#[test]
fn disassemble_rejects_misaligned_and_trailing_input() {
    // not a multiple of 16
    let p = Program { instrs: vec![Instr::Sync, Instr::Halt] };
    let mut bin = p.assemble();
    bin.push(0);
    let e = Program::disassemble(&bin).unwrap_err().to_string();
    assert!(e.contains("not a multiple"), "{e}");

    // trailing garbage after halt
    let p = Program { instrs: vec![Instr::Sync, Instr::Halt, Instr::Sync] };
    let e = Program::disassemble(&p.assemble()).unwrap_err().to_string();
    assert!(e.contains("after halt"), "{e}");

    // a corrupt word names its word/byte offset
    let p = Program { instrs: vec![Instr::Sync, Instr::Halt] };
    let mut bin = p.assemble();
    bin[0] = 0xee; // clobber word 0's opcode
    let e = format!("{:#}", Program::disassemble(&bin).unwrap_err());
    assert!(e.contains("word 0") && e.contains("unknown opcode"), "{e}");
}

fn paper_workloads() -> Vec<j3dai::graph::Graph> {
    vec![models::paper_mbv1(), models::paper_mbv2(), models::paper_seg()]
}

fn compile_programs(g: &j3dai::graph::Graph, cfg: &ArchConfig) -> Vec<Program> {
    compiler::compile(g, cfg).unwrap().cluster_programs
}

fn verify(progs: &[Program], cfg: &ArchConfig) -> VerifyReport {
    verify_programs(progs, cfg, &VerifyPolicy::default())
}

#[test]
fn all_table1_workloads_verify_clean() {
    let cfg = ArchConfig::j3dai();
    for g in paper_workloads() {
        let progs = compile_programs(&g, &cfg);
        let rep = verify(&progs, &cfg);
        assert!(rep.is_clean(), "{}:\n{}", g.name, rep.render_text());
    }
}

#[test]
fn ablation_configs_verify_clean() {
    let g = models::tinycnn(Shape::new(24, 32, 3), 10);
    for cfg in [
        ArchConfig::j3dai(),
        ArchConfig { aiu_enabled: false, ..ArchConfig::j3dai() },
        ArchConfig { dmpa_enabled: false, ..ArchConfig::j3dai() },
        ArchConfig::scaled(2, 8, 8),
    ] {
        let progs = compile_programs(&g, &cfg);
        let rep = verify(&progs, &cfg);
        assert!(rep.is_clean(), "aiu={} dmpa={}:\n{}", cfg.aiu_enabled, cfg.dmpa_enabled, rep.render_text());
    }
}

/// Find a resident local-SRAM load (window strictly inside the cluster
/// SRAM) — the kind of buffer the hazard pass tracks.
fn find_resident_load(progs: &[Program], cap: u64) -> Option<(usize, usize)> {
    for (ci, p) in progs.iter().enumerate() {
        for (pc, i) in p.instrs.iter().enumerate() {
            if let Instr::DmpaLoad { dst_addr, bytes, .. } | Instr::DmaLoad { dst_addr, bytes, .. } = i {
                if *bytes > 0 && (*dst_addr as u64 + *bytes as u64) < cap {
                    return Some((ci, pc));
                }
            }
        }
    }
    None
}

#[test]
fn bounds_mutation_is_caught_on_every_workload() {
    let cfg = ArchConfig::j3dai();
    for g in paper_workloads() {
        let mut progs = compile_programs(&g, &cfg);
        // corrupt the first load's local destination to far outside SRAM
        let pos = progs.iter().position(|p| {
            p.instrs.iter().any(|i| matches!(i, Instr::DmpaLoad { .. } | Instr::DmaLoad { .. }))
        });
        let ci = pos.expect("no loads emitted");
        let pc = progs[ci]
            .instrs
            .iter()
            .position(|i| matches!(i, Instr::DmpaLoad { .. } | Instr::DmaLoad { .. }))
            .unwrap();
        match &mut progs[ci].instrs[pc] {
            Instr::DmpaLoad { dst_addr, .. } | Instr::DmaLoad { dst_addr, .. } => *dst_addr = u32::MAX,
            _ => unreachable!(),
        }
        let rep = verify(&progs, &cfg);
        assert!(!rep.is_clean(), "{}", g.name);
        assert!(rep.diagnostics.iter().any(|d| d.code == "bounds.local-oob"), "{}:\n{}", g.name, rep.render_text());
    }
}

#[test]
fn hazard_mutation_is_caught_on_every_workload() {
    let cfg = ArchConfig::j3dai();
    let cap = cfg.cluster_local_bytes() as u64;
    for g in paper_workloads() {
        let mut progs = compile_programs(&g, &cfg);
        // duplicate a resident load back-to-back: the second rewrite lands
        // before anything consumed the first -> clobber
        let (ci, pc) = find_resident_load(&progs, cap).expect("no resident load");
        let dup = progs[ci].instrs[pc].clone();
        progs[ci].instrs.insert(pc + 1, dup);
        let rep = verify(&progs, &cfg);
        assert!(!rep.is_clean(), "{}", g.name);
        assert!(rep.diagnostics.iter().any(|d| d.code == "hazard.clobber"), "{}:\n{}", g.name, rep.render_text());
    }
}

#[test]
fn protocol_mutation_is_caught_on_every_workload() {
    let cfg = ArchConfig::j3dai();
    for g in paper_workloads() {
        let mut progs = compile_programs(&g, &cfg);
        // drop the `last` flag from a chain-closing ConvTile: the chain
        // never requants -> dangling or broken chain
        let mut mutated = false;
        'outer: for p in progs.iter_mut() {
            for i in p.instrs.iter_mut() {
                if let Instr::ConvTile { last, .. } = i {
                    if *last {
                        *last = false;
                        mutated = true;
                        break 'outer;
                    }
                }
            }
        }
        assert!(mutated, "no closing ConvTile in {}", g.name);
        let rep = verify(&progs, &cfg);
        assert!(!rep.is_clean(), "{}", g.name);
        assert!(
            rep.diagnostics
                .iter()
                .any(|d| d.code == "protocol.chain-dangling" || d.code == "protocol.chain-broken"),
            "{}:\n{}",
            g.name,
            rep.render_text()
        );
    }
}

#[test]
fn structure_mutation_is_caught_on_every_workload() {
    let cfg = ArchConfig::j3dai();
    for g in paper_workloads() {
        // missing halt
        let mut progs = compile_programs(&g, &cfg);
        assert_eq!(progs[0].instrs.pop(), Some(Instr::Halt));
        let rep = verify(&progs, &cfg);
        assert!(rep.diagnostics.iter().any(|d| d.code == "structure.missing-halt"), "{}", g.name);

        // unreachable code after halt
        let mut progs = compile_programs(&g, &cfg);
        progs[0].instrs.push(Instr::Sync);
        let rep = verify(&progs, &cfg);
        assert!(rep.diagnostics.iter().any(|d| d.code == "structure.unreachable"), "{}", g.name);
    }
}

#[test]
fn sarif_export_of_real_workload_parses() {
    let cfg = ArchConfig::j3dai();
    let mut reports = Vec::new();
    for g in paper_workloads() {
        let progs = compile_programs(&g, &cfg);
        // flag TSV crossings so the SARIF has results even on clean models
        let rep = verify_programs(&progs, &cfg, &VerifyPolicy { flag_tsv: true, ..VerifyPolicy::default() });
        assert!(rep.is_clean(), "{}", g.name);
        reports.push((g.name.clone(), rep));
    }
    let doc = Json::parse(&sarif::to_sarif(&reports)).unwrap();
    assert_eq!(doc.get("version").unwrap().as_str(), Some("2.1.0"));
    let runs = doc.get("runs").unwrap().as_arr().unwrap();
    assert_eq!(runs.len(), 3);
    for run in runs {
        let name = run
            .get("tool")
            .unwrap()
            .get("driver")
            .unwrap()
            .get("name")
            .unwrap()
            .as_str()
            .unwrap();
        assert_eq!(name, "j3dai-verify");
    }
    // the plain-JSON summary parses too and counts agree with the reports
    let doc = Json::parse(&sarif::to_json(&reports)).unwrap();
    let entries = doc.get("models").unwrap().as_arr().unwrap();
    assert_eq!(entries.len(), 3);
    for (entry, (_, rep)) in entries.iter().zip(&reports) {
        assert_eq!(entry.get("notes").unwrap().as_f64().unwrap() as usize, rep.note_count());
    }
}
