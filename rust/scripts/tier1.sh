#!/usr/bin/env bash
# Tier-1 verification: release build + test suite, plus clippy/fmt when the
# components are installed (the offline toolchain image may omit them).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

# The PPA gate is the regression the paper lives or dies by — run it by
# name so a filtered `cargo test` configuration can never silently skip it.
echo "== cargo test -q --test ppa_regression"
cargo test -q --test ppa_regression

# Parallelism correctness: cluster-parallel simulation bit-identical to
# serial, multi-worker frame pipeline reassembles in order. Run by name so
# a filtered configuration cannot silently skip the determinism gate.
echo "== cargo test -q --test perf_parallel"
cargo test -q --test perf_parallel

# Fast int8 kernels proven element-for-element against the naive reference
# implementations (registry models + randomized odd shapes/strides).
echo "== cargo test -q --lib sim::functional"
cargo test -q --lib sim::functional

# Static program verifier over every Table I workload: any error-severity
# diagnostic in the compiled cluster programs fails the tier.
echo "== cargo run --release -- lint --model all"
cargo run --release -- lint --model all

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -- -D warnings"
    cargo clippy --all-targets -- -D warnings
else
    echo "== clippy not installed — skipping"
fi

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check"
    cargo fmt --check
else
    echo "== rustfmt not installed — skipping"
fi

echo "tier1 OK"
