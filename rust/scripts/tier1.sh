#!/usr/bin/env bash
# Tier-1 verification: release build + test suite, plus clippy/fmt when the
# components are installed (the offline toolchain image may omit them).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -- -D warnings"
    cargo clippy --all-targets -- -D warnings
else
    echo "== clippy not installed — skipping"
fi

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check"
    cargo fmt --check
else
    echo "== rustfmt not installed — skipping"
fi

echo "tier1 OK"
