//! Ablation: **architecture scalability** — §III-A: "The architecture is
//! scalable at multiple levels" (cluster count, NCB count). Sweeps the
//! array geometry at constant workload (MobileNetV2 @256x192) and reports
//! latency, efficiency and the area the floorplan model assigns — the
//! trade the paper's "top-die-limited" constraint forced.

include!("util.rs");

use j3dai::config::ArchConfig;
use j3dai::models;
use j3dai::power::{area, EnergyModel};
use j3dai::sim;

fn main() {
    header("Ablation: cluster / NCB scalability (MobileNetV2 @256x192)");
    let em = EnergyModel::fdsoi28();
    let g = models::paper_mbv2();

    println!(
        "{:>8} {:>5} {:>4} {:>6} {:>10} {:>9} {:>8} {:>10} {:>10}",
        "clusters", "NCBs", "PEs", "MACs", "cycles", "lat ms", "eff %", "P@30 mW", "die mm2"
    );
    let mut prev_cycles = u64::MAX;
    for (cl, nb, pe) in [
        (1, 16, 8),
        (2, 16, 8),
        (4, 16, 8),
        (6, 8, 8),
        (6, 16, 8), // the J3DAI point
        (6, 32, 8),
        (8, 16, 8),
        (12, 16, 8),
    ] {
        let cfg = ArchConfig::scaled(cl, nb, pe);
        let r = sim::simulate(&g, &cfg).unwrap();
        let die = area::bottom_die(&cfg).used_mm2();
        let star = if (cl, nb, pe) == (6, 16, 8) { " <- J3DAI" } else { "" };
        println!(
            "{cl:>8} {nb:>5} {pe:>4} {:>6} {:>10} {:>9.2} {:>8.1} {:>10.1} {:>10.2}{star}",
            cfg.macs_per_cycle(),
            r.cycles,
            r.latency_ms,
            r.mac_efficiency * 100.0,
            r.power_mw(&em, 30.0).unwrap_or(f64::NAN),
            die
        );
        // Scaling helps monotonically up to the J3DAI point; past it the
        // mapper's split-N fallback broadcasts full inputs to every cluster
        // and the curve reverses — the knee that justifies the paper's
        // "best configuration in terms of scalability" choice of 6x16x8.
        if cl > 1 && cl <= 6 && nb == 16 && pe == 8 {
            assert!(r.cycles <= prev_cycles, "scaling must help up to 6 clusters");
        }
        if nb == 16 && pe == 8 {
            prev_cycles = r.cycles;
        }
    }

    // the J3DAI point must fit the top-die-limited 16 mm^2 budget
    let j = area::bottom_die(&ArchConfig::j3dai());
    assert!(j.used_mm2() < j.outline_mm2);
    println!("\nablation_scaling bench OK");
}
