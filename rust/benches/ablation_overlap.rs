//! Ablation: **load masking / double buffering** — §III-C2: "The
//! scheduling optimization solver looks for the best way to mask parameter
//! loading." Measures the benefit by re-timing the same compiled programs
//! with a barrier after every instruction (no transfer/compute overlap).

include!("util.rs");

use j3dai::compiler;
use j3dai::config::ArchConfig;
use j3dai::graph::Shape;
use j3dai::isa::{Instr, Program};
use j3dai::models;
use j3dai::sim::engine;

/// Serialize a program: Sync after every instruction kills all overlap.
fn serialized(p: &Program) -> Program {
    let mut out = Vec::with_capacity(p.instrs.len() * 2);
    for i in &p.instrs {
        out.push(i.clone());
        if !matches!(i, Instr::Sync | Instr::Halt) {
            out.push(Instr::Sync);
        }
    }
    Program { instrs: out }
}

fn main() {
    header("Ablation: masking parameter loads (double buffering)");
    let cfg = ArchConfig::j3dai();
    println!(
        "{:<28} {:>12} {:>12} {:>10}",
        "model", "overlapped", "serialized", "masked %"
    );
    for g in [
        models::paper_mbv1(),
        models::paper_mbv2(),
        models::paper_seg(),
        models::mobilenet_v1(1, 4, Shape::new(48, 64, 3), 100),
    ] {
        let c = compiler::compile(&g, &cfg).unwrap();
        let mut over = 0u64;
        let mut ser = 0u64;
        for p in &c.cluster_programs {
            over = over.max(engine::run_cluster(&cfg, p, 1).cycles);
            ser = ser.max(engine::run_cluster(&cfg, &serialized(p), 1).cycles);
        }
        let masked = 100.0 * (1.0 - over as f64 / ser as f64);
        println!("{:<28} {:>12} {:>12} {:>9.1}%", g.name, over, ser, masked);
        // the scheduler must actually be hiding transfer time
        assert!(ser > over, "{}: serialization must cost cycles", g.name);
    }
    println!("\nablation_overlap bench OK");
}
