//! Bench: regenerate **Fig. 6** — at-scale chip-size comparison of the
//! two SONY stacked sensors and J3DAI (124 / 262 / 48 mm^2 stacked).

include!("util.rs");

use j3dai::power::area;
use j3dai::report;

fn main() {
    header("Fig. 6 reproduction — chip sizes at scale");
    print!("{}", report::render_fig6());

    let chips = area::fig6_chips();
    let stacked: Vec<f64> = chips.iter().map(|c| c.area_mm2() * c.layers as f64).collect();
    println!("stacked areas: {stacked:.1?} (paper: [124, 262, 48])");
    assert!((stacked[0] - 124.0).abs() < 0.5);
    assert!((stacked[1] - 262.0).abs() < 0.5);
    assert!((stacked[2] - 48.0).abs() < 0.5);
    assert!(chips[2].area_mm2() < chips[0].area_mm2() && chips[2].area_mm2() < chips[1].area_mm2());
    println!("\nfig6 bench OK");
}
