//! Bench: regenerate **Fig. 5** — middle/bottom die floorplans from the
//! component-inventory area model, asserting the paper's constraints
//! (6 mm^2 analog, everything inside the 4.698 x 3.438 mm outline).

include!("util.rs");

use j3dai::config::ArchConfig;
use j3dai::power::area;
use j3dai::report;

fn main() {
    header("Fig. 5 reproduction — die floorplans");
    let cfg = ArchConfig::j3dai();
    let mid = area::middle_die(&cfg);
    let bot = area::bottom_die(&cfg);
    print!("{}", report::render_floorplan(&mid));
    print!("{}", report::render_floorplan(&bot));

    assert!((mid.regions[0].mm2 - 6.0).abs() < 1e-9, "paper: 6 mm^2 analog readout");
    assert!(mid.used_mm2() <= mid.outline_mm2, "middle die must close");
    assert!(bot.used_mm2() <= bot.outline_mm2, "bottom die must close");
    // L2 split: 3 MB bottom vs 2 MB middle -> bottom L2 region is larger
    let l2m = mid.regions.iter().find(|r| r.name.starts_with("L2")).unwrap().mm2;
    let l2b = bot.regions.iter().find(|r| r.name.starts_with("L2")).unwrap().mm2;
    assert!(l2b > l2m);
    println!("\nfig5 bench OK");
}
