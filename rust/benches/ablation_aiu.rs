//! Ablation: **AIU on/off** — §III-B2: "No additional instructions are
//! required to configure the routing control. This reduces the program
//! memory footprint and improves the number of operations per cycle."
//! Measures both effects: program bytes and ops/cycle.

include!("util.rs");

use j3dai::compiler;
use j3dai::config::ArchConfig;
use j3dai::models;
use j3dai::sim;

fn main() {
    header("Ablation: Automatic Index Unit (AIU)");
    let on_cfg = ArchConfig::j3dai();
    let off_cfg = ArchConfig { aiu_enabled: false, ..ArchConfig::j3dai() };

    println!(
        "{:<14} {:>12} {:>12} {:>9} {:>11} {:>11} {:>9}",
        "model", "prog B (on)", "prog B (off)", "size +%", "eff (on)", "eff (off)", "ops/cyc -"
    );
    for g in [models::paper_mbv1(), models::paper_mbv2(), models::paper_seg()] {
        let c_on = compiler::compile(&g, &on_cfg).unwrap();
        let c_off = compiler::compile(&g, &off_cfg).unwrap();
        let r_on = sim::simulate(&g, &on_cfg).unwrap();
        let r_off = sim::simulate(&g, &off_cfg).unwrap();
        let size_pct = 100.0 * (c_off.program_bytes() as f64 / c_on.program_bytes() as f64 - 1.0);
        let opcyc_drop = 100.0 * (1.0 - r_off.mac_efficiency / r_on.mac_efficiency);
        println!(
            "{:<14} {:>12} {:>12} {:>8.1}% {:>10.1}% {:>10.1}% {:>8.2}%",
            g.name,
            c_on.program_bytes(),
            c_off.program_bytes(),
            size_pct,
            r_on.mac_efficiency * 100.0,
            r_off.mac_efficiency * 100.0,
            opcyc_drop
        );
        // both paper claims must hold in the model
        assert!(c_off.program_bytes() > c_on.program_bytes(), "AIU must shrink programs");
        assert!(r_off.mac_efficiency <= r_on.mac_efficiency, "AIU must not hurt ops/cycle");
    }
    println!("\nablation_aiu bench OK");
}
