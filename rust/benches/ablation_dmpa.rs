//! Ablation: **DMPA vs DMA** — the paper's §III-B2 claim that the 1024-bit
//! CCONNECT transfer "is significantly superior to the limitations of DMA,
//! which is constrained by the 64-bit width of the system interconnect".
//! Sweeps transfer sizes (raw bandwidth) and whole-model inference
//! (end-to-end impact with bus contention across 6 clusters).

include!("util.rs");

use j3dai::config::ArchConfig;
use j3dai::graph::Shape;
use j3dai::models;
use j3dai::sim;

fn main() {
    header("Ablation: DMPA vs DMA");
    let cfg = ArchConfig::j3dai();

    println!("raw transfer latency (cycles):");
    println!("{:>12} {:>10} {:>10} {:>8}", "bytes", "DMPA", "DMA", "speedup");
    for bytes in [64u64, 1024, 16 * 1024, 256 * 1024, 1_000_000] {
        let d = cfg.dmpa_cycles(bytes);
        let m = cfg.dma_cycles(bytes);
        println!("{bytes:>12} {d:>10} {m:>10} {:>7.1}x", m as f64 / d as f64);
    }
    // paper: "1 MB in 1000 clock cycles" order of magnitude with DMPA
    assert!(cfg.dmpa_cycles(1_000_000) < 10_000);
    assert!(cfg.dma_cycles(1_000_000) / cfg.dmpa_cycles(1_000_000) >= 15);

    println!("\nend-to-end inference (cycles, with DMA bus contention when DMPA is off):");
    println!("{:<28} {:>12} {:>12} {:>9}", "model", "DMPA on", "DMPA off", "slowdown");
    for g in [
        models::mobilenet_v1(1, 2, Shape::new(96, 128, 3), 100),
        models::mobilenet_v2(1, 2, Shape::new(96, 128, 3), 100),
        models::paper_mbv1(),
        models::paper_mbv2(),
    ] {
        let on = sim::simulate(&g, &cfg).unwrap();
        let off_cfg = ArchConfig { dmpa_enabled: false, ..cfg.clone() };
        let off = sim::simulate(&g, &off_cfg).unwrap();
        let slow = off.cycles as f64 / on.cycles as f64;
        println!("{:<28} {:>12} {:>12} {:>8.2}x", g.name, on.cycles, off.cycles, slow);
        assert!(slow > 1.5, "{}: DMPA must matter", g.name);
    }
    println!("\nablation_dmpa bench OK");
}
