//! Perf bench: wallclock of the L3 hot paths — compile, cycle-sim,
//! functional PE model, and PJRT inference. The §Perf targets in
//! EXPERIMENTS.md are tracked here (simulator >= 1e8 modeled MACs/s,
//! full-model sim well under 1 s).

include!("util.rs");

use j3dai::config::ArchConfig;
use j3dai::models;
use j3dai::runtime::{self, Runtime};
use j3dai::sim;
use j3dai::sim::functional::{self, Tensor};

fn main() {
    header("perf: compile + simulate wallclock");
    let cfg = ArchConfig::j3dai();
    for g in [models::paper_mbv1(), models::paper_mbv2(), models::paper_seg()] {
        let (mean, min) = time_ms(5, || {
            let _ = sim::simulate(&g, &cfg).unwrap();
        });
        let r = sim::simulate(&g, &cfg).unwrap();
        let macs_per_s = r.total_macs as f64 / (min / 1e3);
        println!(
            "{:<14} {:>7.1} ms mean / {:>7.1} ms min  -> {:.2e} modeled MACs/s",
            g.name, mean, min, macs_per_s
        );
        assert!(min < 1000.0, "full-model sim must stay under 1 s");
        assert!(macs_per_s > 1e8, "simulator throughput target (EXPERIMENTS.md §Perf)");
    }

    header("perf: traced sim overhead (span collection on)");
    {
        let g = models::paper_mbv1();
        let sample = |f: &mut dyn FnMut()| -> Vec<f64> {
            (0..5)
                .map(|_| {
                    let t = std::time::Instant::now();
                    f();
                    t.elapsed().as_secs_f64() * 1e3
                })
                .collect()
        };
        let plain = sample(&mut || drop(sim::simulate(&g, &cfg)));
        let traced = sample(&mut || drop(sim::simulate_traced(&g, &cfg)));
        let (p50p, p50t) = (percentile_ms(&plain, 50.0), percentile_ms(&traced, 50.0));
        println!(
            "mbv1 sim p50: {:.2} ms plain / {:.2} ms traced ({:+.1}%)",
            p50p,
            p50t,
            (p50t / p50p - 1.0) * 100.0
        );
    }

    header("perf: functional PE model (tinycnn, full integer interpret)");
    let g = models::artifact_graph("tinycnn_24x32").unwrap();
    let x = functional::synthetic_input("tinycnn_24x32", g.input);
    let (mean, min) = time_ms(10, || {
        let _ = functional::run_final(&g, &x);
    });
    println!("tinycnn functional: {mean:.2} ms mean / {min:.2} ms min");

    header("perf: PJRT inference service time");
    if runtime::default_artifact_dir().join("manifest.txt").exists() {
        let mut rt = Runtime::new().unwrap();
        rt.load_all(&runtime::default_artifact_dir()).unwrap();
        for name in ["tinycnn_24x32", "mbv1_w25_48x64", "fpnseg_w25_48x64"] {
            let e = rt.entry(name).unwrap().clone();
            let frame = Tensor::new(e.input_shape, std::fs::read(&e.input_path).unwrap());
            // warmup
            let _ = rt.infer(name, &frame).unwrap();
            let (mean, min) = time_ms(20, || {
                let _ = rt.infer(name, &frame).unwrap();
            });
            println!("{name:<18} {mean:>7.2} ms mean / {min:>7.2} ms min per inference");
        }
    } else {
        println!("artifacts not built — skipping PJRT timing");
    }
    println!("\nperf_sim bench OK");
}
