//! Bench: regenerate **Table II** — the cross-chip comparison. The SONY
//! columns are the paper's reported constants (it is a literature
//! comparison in the paper too); the J3DAI column is computed end-to-end
//! by our compiler + simulator + power/area models on MobileNetV2.

include!("util.rs");

use j3dai::config::ArchConfig;
use j3dai::models;
use j3dai::power::EnergyModel;
use j3dai::{report, sim};

fn main() {
    header("TABLE II reproduction");
    let cfg = ArchConfig::j3dai();
    let em = EnergyModel::fdsoi28();
    let mbv2 = sim::simulate(&models::paper_mbv2(), &cfg).unwrap();

    let mut cols = report::sony_columns();
    cols.push(report::j3dai_column(&cfg, &mbv2, &em));
    print!("{}", report::render_table2(&cols));

    let j = cols.last().unwrap();
    println!("\npaper J3DAI column: eff 46.6%, 186.7 mW, 3.01 ms, 0.62 TOPS/W, 12.9 GOPS/W/mm2");
    println!(
        "ours:               eff {:.1}%, {:.1} mW, {:.2} ms, {:.2} TOPS/W, {:.1} GOPS/W/mm2",
        j.mac_eff_pct,
        j.power_mw_200fps.unwrap(),
        j.time_ms_262.unwrap(),
        j.tops_per_w.unwrap(),
        j.gops_w_mm2().unwrap()
    );

    // the paper's comparative claims must hold
    for sony in &cols[..2] {
        assert!(j.gops_w_mm2().unwrap() > sony.gops_w_mm2().unwrap(), "J3DAI must win GOPS/W/mm2");
        assert!(j.chip_mm2 < sony.chip_mm2, "J3DAI must be most compact");
        assert!(j.power_mw_200fps.unwrap() > sony.power_mw_200fps.unwrap(), "J3DAI has highest power in the paper");
    }
    println!("\ntable2 bench OK");
}
