//! Bench: regenerate **Table I** — key performance metrics of the three
//! paper workloads (MMACs, latency @200 MHz, power @30/200 FPS, TOPs/W,
//! MAC/cycle efficiency), printed next to the paper's reported values.

include!("util.rs");

use j3dai::config::ArchConfig;
use j3dai::models;
use j3dai::power::EnergyModel;
use j3dai::{report, sim};

fn main() {
    header("TABLE I reproduction (full compile + cycle simulation)");
    let cfg = ArchConfig::j3dai();
    let em = EnergyModel::fdsoi28();

    let mut rows = Vec::new();
    for (g, input) in [
        (models::paper_mbv1(), "256x192"),
        (models::paper_mbv2(), "256x192"),
        (models::paper_seg(), "512x384"),
    ] {
        let (mean, min) = time_ms(3, || {
            let _ = sim::simulate(&g, &cfg).unwrap();
        });
        let r = sim::simulate(&g, &cfg).unwrap();
        println!("simulated {} in {mean:.1} ms (min {min:.1} ms) wallclock", g.name);
        rows.push(report::table1_row(&r, &em, input));
    }
    println!();
    print!("{}", report::render_table1(&rows));

    // machine-checkable acceptance of the reproduction shape
    assert!(rows[0].latency_ms < rows[2].latency_ms);
    assert!(rows[1].latency_ms < rows[0].latency_ms);
    assert!(rows[0].mac_eff > rows[1].mac_eff + 0.15);
    assert!(rows[2].power_mw_200.is_none(), "seg must not sustain 200 FPS");
    println!("\ntable1 bench OK");
}
