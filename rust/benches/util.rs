// Shared helpers for the harness-less benches (no criterion offline).
// Each bench `include!`s this file.

use std::time::Instant;

/// Time a closure over `iters` iterations; returns (mean_ms, min_ms).
#[allow(dead_code)]
pub fn time_ms<F: FnMut()>(iters: u32, mut f: F) -> (f64, f64) {
    let mut min = f64::MAX;
    let mut total = 0.0;
    for _ in 0..iters {
        let t = Instant::now();
        f();
        let ms = t.elapsed().as_secs_f64() * 1e3;
        total += ms;
        min = min.min(ms);
    }
    (total / iters as f64, min)
}

#[allow(dead_code)]
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

/// Percentile of a sample buffer in ms — delegates to the crate's shared
/// ceil-rank implementation so benches report the same tail definition as
/// the coordinator and report modules.
#[allow(dead_code)]
pub fn percentile_ms(samples: &[f64], p: f64) -> f64 {
    let mut v = samples.to_vec();
    j3dai::telemetry::percentile_unsorted(&mut v, p)
}
